"""Autotuner vs naive grid search (DESIGN.md §7.1).

Runs the exhaustive ``search_disaggregation`` and the pruned/warm-started
``autotune_disaggregation`` over the full 8-GPU llava-1.5-7b candidate grid
and reports simulation counts, wall-clock, and argmax agreement.

Acceptance: same best DisaggConfig, >= 3x fewer simulations.
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.autotuner import autotune_disaggregation
from repro.core.costmodel import H800
from repro.core.hybrid_epd import enumerate_disaggs, search_disaggregation
from repro.data.workload import IMAGE_TOKENS, PROFILES, slo_for

MODEL = "llava-1.5-7b"
DATASET = "textcaps"
N_GPUS = 8
N_REQUESTS = 240
# high enough that the best candidate saturates *below* the cap — the
# optimum is interior, so pruning, warm starts, and caching all do work
MAX_RATE = 1024.0


def run():
    cfg = get_config(MODEL)
    profile = PROFILES[DATASET]
    slo = slo_for(MODEL, DATASET)
    img = IMAGE_TOKENS[MODEL]
    cands = enumerate_disaggs(N_GPUS)

    t0 = time.perf_counter()
    ex = search_disaggregation(cfg, H800, profile, slo, candidates=cands,
                               image_tokens=img, n_requests=N_REQUESTS,
                               max_rate=MAX_RATE)
    ex_wall = time.perf_counter() - t0

    au = autotune_disaggregation(cfg, H800, profile, slo, candidates=cands,
                                 image_tokens=img, n_requests=N_REQUESTS,
                                 max_rate=MAX_RATE)

    sim_ratio = ex.n_sims / max(au.n_sims, 1)
    return [
        (f"autotuner/exhaustive", ex_wall * 1e6,
         f"best={ex.disagg.name};goodput={ex.goodput:.1f};"
         f"sims={ex.n_sims};candidates={len(cands)}"),
        (f"autotuner/autotuned", au.wall_s * 1e6,
         f"best={au.disagg.name};goodput={au.goodput:.1f};"
         f"sims={au.n_sims};pruned={au.n_pruned}"),
        (f"autotuner/speedup", 0.0,
         f"sim_ratio={sim_ratio:.1f}x;wall_ratio={ex_wall/au.wall_s:.1f}x;"
         f"same_argmax={ex.disagg.name == au.disagg.name}"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
