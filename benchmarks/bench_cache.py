"""Prefix + image-embedding cache benchmark (DESIGN.md §14).

Drives the cache-sensitive traces from ``repro.data.workload`` — multi-turn
conversations (each turn resends the whole history) and repeated-image VQA
(a Zipf-hot shared image pool) — through two otherwise-identical live
``Engine`` instances, one with ``prefix_cache=True`` and one without, on a
single EPD instance of reduced LLaVA-1.5-7B.  Greedy parity guarantees
both engines emit identical tokens, so the turn-t prompt bodies (history =
prior prompts + prior outputs) are byte-identical across the two runs and
the comparison isolates the cache.

Multi-turn rounds run closed-loop (turn t needs turn t-1's output); the
image trace submits in arrival order.  Per-request TTFT comes from the
``Request`` lifecycle timestamps; hit rates, COW copies, and evictions
come from ``Engine.cache_stats()``.  Results land in ``BENCH_cache.json``
(separate from ``BENCH_serving.json``, which stays cache-off).

The headline P90 compares the **steady-state population**: requests that
share a prefix or image with an earlier request (turn >= 1, or a repeat
of an already-seen image).  Cold requests — conversation openers and
first sightings of an image — are byte-identical work in both engines by
construction (no cache can help them), so they are reported separately
(``p90_ttft_cold_s``) rather than letting their constant cost set the
tail of both runs and mask the comparison.

A warmup pass with the same shapes but different token values pre-compiles
the jit buckets on each engine without seeding the measured prompts into
the cache (warmup prompts never match measured ones).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

# knobs (smoke tests monkeypatch these down).  The conversation shape is
# prefill-dominant on purpose — a long shared system prompt with short
# fresh turns is exactly the regime prefix caching targets (and the
# common production shape); short outputs keep decode steps from
# drowning the TTFT signal at reduced-model scale.
N_CONVS = 4          # concurrent multi-turn conversations
TURNS = 3            # turns per conversation (turn t resends the history)
SYSTEM_TOKENS = 128
TURN_TOKENS = 16
N_IMG_REQS = 8       # repeated-image VQA requests
IMAGE_POOL = 3       # distinct images behind the Zipf pool
RATE = 4.0           # arrival rate for the image trace, requests/s
MAX_NEW = 4
KV_BLOCKS = 256
SLO_TTFT = 2.5
SLO_TPOT = 0.25

_params_cache: dict = {}


def _drive(prefix_cache: bool, seed: int):
    import jax

    from repro.configs import get_config
    from repro.core.request import SLO, SamplingParams
    from repro.core.simulator import DisaggConfig
    from repro.data.workload import repeated_image_trace
    from repro.engine.api import Engine
    from repro.models import model as M

    cfg = get_config("llava-1.5-7b").reduced()
    if "p" not in _params_cache:
        _params_cache["p"] = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, _params_cache["p"], DisaggConfig({"EPD": 1}),
                    slo=SLO(SLO_TTFT, SLO_TPOT), kv_blocks=KV_BLOCKS,
                    prefix_cache=prefix_cache)
    sp = SamplingParams(max_tokens=MAX_NEW)
    rng = np.random.default_rng(seed)
    reqs, steady = [], []
    engine.start()
    try:
        # --- multi-turn conversations (closed loop per turn round) -------
        hist = {c: list(rng.integers(0, cfg.vocab_size, SYSTEM_TOKENS))
                for c in range(N_CONVS)}
        for turn in range(TURNS):
            rids = []
            for c in range(N_CONVS):
                hist[c] += list(rng.integers(0, cfg.vocab_size, TURN_TOKENS))
                rids.append((c, engine.submit(
                    np.asarray(hist[c], np.int32), sampling=sp)))
            if not engine.wait([r for _, r in rids], timeout=600.0):
                raise RuntimeError("cache bench timed out (multi-turn)")
            for c, rid in rids:
                item = engine.result(rid)
                hist[c] += list(item.generated)
                reqs.append(item.req)
                steady.append(turn > 0)
        # --- repeated-image VQA (Zipf-hot pool, arrival order) -----------
        # trace structure (lengths, arrivals, image ids) is fixed so the
        # warmup pass compiles exactly the measured jit buckets; only the
        # token/pixel values vary with ``seed``
        pool = [(rng.standard_normal((cfg.media_tokens, cfg.d_model))
                 * 0.1).astype(np.float32) for _ in range(IMAGE_POOL)]
        trace = repeated_image_trace(n=N_IMG_REQS, rate=RATE,
                                     image_pool=IMAGE_POOL, seed=0)
        t0 = time.monotonic()
        rids, seen = [], set()
        for it in trace:
            lag = it.arrival - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            prompt = rng.integers(0, cfg.vocab_size,
                                  it.new_tokens).astype(np.int32)
            rids.append(engine.submit(prompt, media=pool[it.image_id],
                                      sampling=sp))
            steady.append(it.image_id in seen)
            seen.add(it.image_id)
        if not engine.wait(rids, timeout=600.0):
            raise RuntimeError("cache bench timed out (images)")
        reqs += [engine.result(r).req for r in rids]
        stats = engine.cache_stats()
    finally:
        engine.close()
    return reqs, steady, stats


def _p90_ttft(reqs, flags=None, want=True) -> float:
    from repro.core.metrics import quantile
    if flags is None:
        flags = [want] * len(reqs)
    ttfts = [r.ttft() for r, f in zip(reqs, flags)
             if f == want and r.ttft() is not None]
    return quantile(ttfts, 0.9)


def run(out=None):
    # warmup compiles each engine's jit buckets; seed 1000 keeps warmup
    # prompt bodies disjoint from the measured ones (no false cache hits)
    _drive(False, seed=1000)
    reqs_off, steady, _ = _drive(False, seed=0)
    _drive(True, seed=1000)
    reqs_on, _, stats = _drive(True, seed=0)

    p90_off = _p90_ttft(reqs_off, steady)
    p90_on = _p90_ttft(reqs_on, steady)
    speedup = p90_off / p90_on if p90_on > 0 else float("inf")
    results = {
        "n_requests": len(reqs_on),
        "n_steady": sum(steady),
        # steady-state = shares a prefix/image with an earlier request;
        # cold requests are identical work in both engines (see docstring)
        "p90_ttft_on_s": p90_on,
        "p90_ttft_off_s": p90_off,
        "ttft_speedup": speedup,
        "p90_ttft_cold_s": {"on": _p90_ttft(reqs_on, steady, want=False),
                            "off": _p90_ttft(reqs_off, steady, want=False)},
        "p90_ttft_all_s": {"on": _p90_ttft(reqs_on),
                           "off": _p90_ttft(reqs_off)},
        "prefix_hit_rate": stats["prefix_hit_rate"],
        "encode_hit_rate": stats["encode_hit_rate"],
        "cow_copies": stats["cow_copies"],
        "evictions": stats["evictions"],
        "trace": {"n_convs": N_CONVS, "turns": TURNS,
                  "system_tokens": SYSTEM_TOKENS, "turn_tokens": TURN_TOKENS,
                  "n_img_reqs": N_IMG_REQS, "image_pool": IMAGE_POOL},
    }
    import jax
    results["backend"] = jax.default_backend()
    if out is None:
        out = Path(__file__).resolve().parent.parent / "BENCH_cache.json"
    Path(out).write_text(json.dumps(results, indent=2) + "\n")
    return [
        ("cache/p90_ttft_on", p90_on * 1e6, f"p90_ttft={p90_on:.3f}s"),
        ("cache/p90_ttft_off", p90_off * 1e6, f"p90_ttft={p90_off:.3f}s"),
        ("cache/ttft_speedup", 0.0, f"speedup={speedup:.2f}x"),
        ("cache/hit_rates", 0.0,
         f"prefix={stats['prefix_hit_rate']:.2%} "
         f"encode={stats['encode_hit_rate']:.2%}"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
