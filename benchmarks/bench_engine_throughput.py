"""End-to-end engine decode throughput: dense-gather vs device-paged.

Drives ``HydraServer`` (encode + prefill + decode, reduced LLaVA-1.5-7B,
single EPD instance) with the same B=8 multimodal workload under each
decode backend:

  dense            : the seed fallback (``device_cache=False``) — every
                     decode step round-trips the whole KV cache between
                     host numpy and device AND retraces/compiles for each
                     novel (batch, max-context) shape, because context
                     lengths grow every step
  paged-interpret  : the device-resident path (DESIGN.md §11) — Pallas
                     paged-attention + fused cache-write over block tables
                     in interpret mode (the CPU default), bucketed jit
                     shapes so steady state never recompiles
  paged-ref        : same paged semantics through the pure-jnp oracles
                     (``REPRO_PAGED_IMPL=ref``), the fastest CPU option

Each server is warmed with a *different* random workload first: that fully
warms the paged paths (their shape buckets are workload-independent) while
leaving the dense path its production behavior of recompiling on the novel
context-length trajectory — exactly the host-bound cost the paged decode
eliminates.  Only decode calls are timed (wall clock around
``ModelRunner.decode``).  Results land in ``BENCH_engine.json`` at the repo
root; the acceptance bar is paged-interpret >= 3x dense tokens/s at B=8.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import numpy as np

B = 8                # concurrent requests (acceptance point)
MAX_NEW = 18         # max context 24 text + 16 media + 18 <= 64 (4 KV pages)


class _DecodeTimer:
    """Wraps a runner's decode entry point, accumulating wall time/tokens."""

    def __init__(self, runner):
        self.seconds = 0.0
        self.tokens = 0
        self._decode = runner.decode
        runner.decode = self._timed

    def _timed(self, rids, toks, *a, **kw):
        t0 = time.perf_counter()
        out = self._decode(rids, toks, *a, **kw)
        self.seconds += time.perf_counter() - t0
        self.tokens += len(rids)
        return out


def _submit_batch(srv, cfg, rng):
    for _ in range(B):
        n = int(rng.integers(8, 25))  # heterogeneous context lengths
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                 * 0.1).astype(np.float32)
        srv.submit(prompt, media=media, max_new_tokens=MAX_NEW)


def _drive(device_cache: bool):
    from repro.configs import get_config
    from repro.core.simulator import DisaggConfig
    from repro.engine.server import HydraServer
    from repro.models import model as M

    cfg = get_config("llava-1.5-7b").reduced()
    if "p" not in _drive._params:
        _drive._params["p"] = M.init_params(cfg, jax.random.PRNGKey(0))
    params = _drive._params["p"]
    # pool sized to the workload (8 requests x <=64 tokens + headroom):
    # interpret-mode kernel emulation copies scale with pool size
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}),
                      device_cache=device_cache, kv_blocks=64)
    # warm the server on a different random workload: paged shape buckets
    # are workload-independent, the dense path keeps retracing in the
    # measured run (its per-step shapes are novel there, as in production)
    _submit_batch(srv, cfg, np.random.default_rng(1))
    srv.run()
    timers = [_DecodeTimer(i.runner) for i in srv.instances]
    _submit_batch(srv, cfg, np.random.default_rng(0))
    srv.run()
    secs = sum(t.seconds for t in timers)
    toks = sum(t.tokens for t in timers)
    return toks / max(secs, 1e-12), toks


_drive._params = {}


def run(out=None):
    rows = []
    results = {}
    variants = [("dense", False, None),
                ("paged-interpret", True, "interpret"),
                ("paged-ref", True, "ref")]
    if jax.default_backend() == "tpu":
        variants.append(("paged-kernel", True, "kernel"))
    for name, device_cache, impl in variants:
        prev = os.environ.pop("REPRO_PAGED_IMPL", None)
        if impl:
            os.environ["REPRO_PAGED_IMPL"] = impl
        try:
            tok_per_s, toks = _drive(device_cache)
        finally:
            os.environ.pop("REPRO_PAGED_IMPL", None)
            if prev:
                os.environ["REPRO_PAGED_IMPL"] = prev
        results[name] = {"decode_tokens_per_s": tok_per_s,
                         "decode_tokens": toks, "batch": B}
        rows.append((f"engine/decode/{name}", 1e6 / tok_per_s,
                     f"tok_per_s={tok_per_s:.1f}"))
    speedup = (results["paged-interpret"]["decode_tokens_per_s"]
               / results["dense"]["decode_tokens_per_s"])
    results["speedup"] = speedup
    results["backend"] = jax.default_backend()
    if out is None:
        out = Path(__file__).resolve().parent.parent / "BENCH_engine.json"
    Path(out).write_text(json.dumps(results, indent=2) + "\n")
    rows.append(("engine/decode/speedup", 0.0, f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
