"""Instance-failure recovery benchmark (DESIGN.md §15).

An open-loop Poisson run against a live streaming ``Engine`` on TWO hybrid
EPD instances, with a ``FaultPlan`` killing instance 1 mid-run.  The dead
instance's stranded requests re-dispatch to the survivor via journal
replay — re-prefilling prompt + already-emitted tokens and resuming decode
at the exact per-lane PRNG step — so the run must lose ZERO requests and,
under greedy decoding, every request's token ids must match an
uninterrupted baseline run of the same seeded workload bit-for-bit.

Reported (``BENCH_faults.json``):
  lost_requests          finishes other than length/stop (must be 0)
  token_parity           per-request id match vs. the no-fault baseline
  recovery_s             instance death -> last affected request streaming
                         tokens again
  attainment pre/post    SLO attainment of requests finished before the
                         fault vs. submitted after it (steady-state on the
                         surviving capacity)

The baseline pass doubles as the control for the "FaultPlan disabled means
nothing changes" invariant: it runs on the identical engine/workload with
``fault_plan=None``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

# knobs (smoke tests monkeypatch these down)
N = 14               # measured requests per pass
RATE = 3.0           # Poisson arrival rate, requests/s
MAX_NEW = 8
PROMPT_LO, PROMPT_HI = 8, 20
P_IMAGE = 0.5
SLO_TTFT = 2.5
SLO_TPOT = 0.25
KV_BLOCKS = 96
CRASH_ITER = 12      # productive scheduler iteration at which inst 1 dies

_params_cache: dict = {}


def _requests(cfg, seed: int):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(N):
        n = int(rng.integers(PROMPT_LO, PROMPT_HI))
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        media = None
        if rng.random() < P_IMAGE:
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        out.append((prompt, media))
    gaps = rng.exponential(1.0 / RATE, size=N)
    return out, np.cumsum(gaps)


def _submit_all(engine, bodies, arrivals):
    from repro.core.request import SamplingParams

    t0 = time.monotonic()
    rids = []
    for i, (prompt, media) in enumerate(bodies):
        if arrivals is not None:
            lag = arrivals[i] - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
        rids.append(engine.submit(
            prompt, media=media, sampling=SamplingParams(max_tokens=MAX_NEW)))
    if not engine.wait(rids, timeout=600.0):
        raise RuntimeError("fault-recovery bench timed out")
    return rids, time.monotonic() - t0


def _make_engine(cfg):
    import jax

    from repro.core.request import SLO
    from repro.core.simulator import DisaggConfig
    from repro.engine.api import Engine

    from repro.models import model as M

    if "p" not in _params_cache:
        _params_cache["p"] = M.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, _params_cache["p"], DisaggConfig({"EPD": 2}),
                  slo=SLO(SLO_TTFT, SLO_TPOT), kv_blocks=KV_BLOCKS,
                  prefix_cache=True)


def _drive():
    from repro.configs import get_config
    from repro.engine.faults import FaultEvent, FaultPlan

    cfg = get_config("llava-1.5-7b").reduced()
    engine = _make_engine(cfg)
    bodies, arrivals = _requests(cfg, seed=0)
    engine.start()
    try:
        # warmup (same shapes -> same jit buckets), then the no-fault
        # baseline pass, then the same workload with instance 1 crashing
        _submit_all(engine, bodies, arrivals=None)
        _submit_all(engine, bodies, arrivals)
        base_rids, base_horizon = _submit_all(engine, bodies, arrivals)
        base = [(list(engine.result(r).generated), engine.result(r).req)
                for r in base_rids]
        with engine._cv:
            engine.server.fault_plan = FaultPlan(
                [FaultEvent(CRASH_ITER, "crash", iid=1)])
            engine.server._iter = 0
        fault_rids, horizon = _submit_all(engine, bodies, arrivals)
        fault = [(list(engine.result(r).generated), engine.result(r).req)
                 for r in fault_rids]
        stats = engine.server.fault_stats()
    finally:
        engine.server.fault_plan = None
        engine.close()
    return base, base_horizon, fault, horizon, stats


def run(out=None):
    from repro.core.metrics import summarize

    base, base_horizon, fault, horizon, stats = _drive()
    lost = sum(1 for _, r in fault
               if r.finish_reason not in ("length", "stop"))
    parity = sum(1 for (bt, _), (ft, _) in zip(base, fault) if bt == ft)

    dead = [e for e in stats["log"] if e["kind"] == "instance_dead"]
    replayed = {e["rid"] for e in stats["log"] if e["kind"] == "replay"}
    recovery_s = 0.0
    if dead:
        t_dead = dead[0]["t"]
        resumed = [min((t for t in r.token_times if t > t_dead),
                       default=None)
                   for _, r in fault if r.rid in replayed]
        if resumed and all(t is not None for t in resumed):
            recovery_s = max(resumed) - t_dead

    pre = [r for _, r in fault
           if dead and r.finish_time is not None
           and r.finish_time <= dead[0]["t"]]
    post = [r for _, r in fault if dead and r.arrival > dead[0]["t"]]
    att = lambda rs: (sum(1 for r in rs if r.meets_slo()) / len(rs)
                      if rs else None)
    s = summarize([r for _, r in fault], RATE, horizon)

    results = {
        "n_requests": len(fault),
        "rate_rps": RATE,
        "crash_iteration": CRASH_ITER,
        "lost_requests": lost,
        "token_parity": {"matched": parity, "total": len(fault)},
        "replays": stats["replays"],
        "shed": stats["shed"],
        "dead_instances": stats["dead_instances"],
        "recovery_s": recovery_s,
        "attainment_pre_fault": att(pre),
        "attainment_post_fault": att(post),
        "attainment_overall": s.attainment,
        "attainment_baseline": (
            sum(1 for _, r in base if r.meets_slo()) / len(base)),
        "p90_ttft_s": s.p90_ttft,
        "horizon_s": horizon,
        "baseline_horizon_s": base_horizon,
    }
    import jax
    results["backend"] = jax.default_backend()
    if out is None:
        out = Path(__file__).resolve().parent.parent / "BENCH_faults.json"
    Path(out).write_text(json.dumps(results, indent=2) + "\n")
    return [
        ("faults/lost", 0.0, f"lost={lost}"),
        ("faults/parity", 0.0, f"parity={parity}/{len(fault)}"),
        ("faults/recovery", recovery_s * 1e6,
         f"recovery={recovery_s:.3f}s"),
        ("faults/attainment", 0.0,
         f"attainment={s.attainment:.2%} "
         f"(baseline={results['attainment_baseline']:.2%})"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
