"""Paper Fig 10: SLO attainment / goodput vs baseline engines across the
five datasets (8xH800, paper Table 3 SLOs).

Baselines are the scheduling policies the respective engines use, run on
8 colocated instances (vLLM-v0 = prefill_first, vLLM-v1 = decode_first,
SGLang/TGI-class chunked = sarathi).  HydraInfer = Algorithm 1 + the best
hybrid-EPD disaggregation from a small candidate search.

Paper claim validated: up to 2x/1.5x/2x/2x/4x goodput improvement on
MME/POPE/TextCaps/TextVQA/VizWiz (model-dependent, >= ~1.5x typical).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.metrics import slo_attainment
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests, slo_for

MODEL = "llava-next-7b"
RATES = (4.0, 8.0, 16.0, 24.0, 32.0, 48.0, 64.0, 96.0, 128.0,
         192.0, 256.0)
HYDRA_CANDS = (DisaggConfig({"EPD": 8}), DisaggConfig({"EP": 4, "D": 4}),
               DisaggConfig({"ED": 4, "P": 4}),
               DisaggConfig({"E": 1, "P": 3, "D": 4}),
               DisaggConfig({"EP": 2, "D": 6}))


def _attain(cfg, ds, disagg, policy, rate, slo, img_tokens, n=120):
    reqs = make_requests(PROFILES[ds], rate=rate, n=n,
                         image_tokens_per_image=img_tokens, slo=slo, seed=0)
    cl = Cluster(cfg, H800, disagg, slo, policy_name=policy)
    done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 120)
    return slo_attainment(done)


def _goodput(cfg, ds, disagg, policy, slo, img_tokens):
    best = 0.0
    for rate in RATES:
        if _attain(cfg, ds, disagg, policy, rate, slo, img_tokens) >= 0.9:
            best = rate
        else:
            break
    return best


def run(datasets=("textcaps", "pope", "mme", "textvqa", "vizwiz")):
    rows = []
    cfg = get_config(MODEL)
    img_tokens = IMAGE_TOKENS[MODEL]
    for ds in datasets:
        slo = slo_for(MODEL, ds)
        base = {}
        for policy, label in (("prefill_first", "vllm-v0"),
                              ("decode_first", "vllm-v1"),
                              ("sarathi", "sarathi-chunked")):
            g = _goodput(cfg, ds, DisaggConfig({"EPD": 8}), policy, slo,
                         img_tokens)
            base[label] = g
            rows.append((f"fig10/{ds}/{label}", 0.0, f"goodput_rps={g:.1f}"))
        # hydra: best disaggregation among candidates (profile-driven)
        gh, best_dc = 0.0, None
        for dc in HYDRA_CANDS:
            g = _goodput(cfg, ds, dc, "hydra", slo, img_tokens)
            if g > gh:
                gh, best_dc = g, dc
        ref = max(base.values()) or 1e-9
        rows.append((f"fig10/{ds}/hydrainfer", 0.0,
                     f"goodput_rps={gh:.1f};best={best_dc.name};"
                     f"vs_best_baseline={gh / ref:.2f}x"))
    return rows
