"""Paper Fig 11: impact of node ratios on TTFT/TPOT for each
disaggregation method (TextCaps, fixed request rate)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.metrics import summarize
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests, slo_for

MODEL = "llava-next-7b"
RATE = 24.0


def run():
    rows = []
    cfg = get_config(MODEL)
    slo = slo_for(MODEL, "textcaps")
    cands = []
    for k in range(1, 8):
        cands.append(DisaggConfig({"EP": k, "D": 8 - k}))
        cands.append(DisaggConfig({"ED": k, "P": 8 - k}))
    for e in (1, 2):
        for p in range(1, 8 - e):
            cands.append(DisaggConfig({"E": e, "P": p, "D": 8 - e - p}))
    for dc in cands:
        reqs = make_requests(PROFILES["textcaps"], rate=RATE, n=150,
                             image_tokens_per_image=IMAGE_TOKENS[MODEL],
                             slo=slo, seed=0)
        cl = Cluster(cfg, H800, dc, slo)
        done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 180)
        s = summarize(done, RATE, reqs[-1].arrival)
        rows.append((f"fig11/{dc.name}", 0.0,
                     f"p90_ttft_s={s.p90_ttft:.3f};p90_tpot_ms="
                     f"{s.p90_tpot*1e3:.1f};done={len(done)}"))
    return rows
