"""Paper Fig 11: impact of node ratios on TTFT/TPOT for each
disaggregation method (TextCaps, fixed request rate).

``--hetero`` adds a heterogeneous sweep (DESIGN.md §7.2): the same ratios
on a 4xH800 + 4xL40S cluster, with the autotuner picking the best per-role
hardware assignment and reporting its search wall-clock.

Run:  PYTHONPATH=src python -m benchmarks.bench_fig11_node_ratio [--hetero]
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.costmodel import H800, L40S
from repro.core.metrics import summarize
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests, slo_for

MODEL = "llava-next-7b"
RATE = 24.0


def _simulate_rows(cfg, slo, cands, prefix):
    rows = []
    for dc in cands:
        reqs = make_requests(PROFILES["textcaps"], rate=RATE, n=150,
                             image_tokens_per_image=IMAGE_TOKENS[MODEL],
                             slo=slo, seed=0)
        cl = Cluster(cfg, H800, dc, slo)
        done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 180)
        s = summarize(done, RATE, reqs[-1].arrival)
        rows.append((f"{prefix}/{dc.name}", 0.0,
                     f"p90_ttft_s={s.p90_ttft:.3f};p90_tpot_ms="
                     f"{s.p90_tpot*1e3:.1f};done={len(done)}"))
    return rows


def run(hetero: bool = False):
    cfg = get_config(MODEL)
    slo = slo_for(MODEL, "textcaps")
    cands = []
    for k in range(1, 8):
        cands.append(DisaggConfig({"EP": k, "D": 8 - k}))
        cands.append(DisaggConfig({"ED": k, "P": 8 - k}))
    for e in (1, 2):
        for p in range(1, 8 - e):
            cands.append(DisaggConfig({"E": e, "P": p, "D": 8 - e - p}))
    rows = _simulate_rows(cfg, slo, cands, "fig11")
    if hetero:
        rows += run_hetero()
    return rows


def run_hetero():
    """Autotuned search over per-role hardware assignments on a
    heterogeneous 4xH800 + 4xL40S cluster."""
    from repro.core.autotuner import (autotune_disaggregation,
                                      enumerate_hetero_disaggs)

    cfg = get_config(MODEL)
    slo = slo_for(MODEL, "textcaps")
    pools = [(H800, 4), (L40S, 4)]
    cands = enumerate_hetero_disaggs(pools)
    t0 = time.perf_counter()
    res = autotune_disaggregation(cfg, H800, PROFILES["textcaps"], slo,
                                  candidates=cands,
                                  image_tokens=IMAGE_TOKENS[MODEL],
                                  n_requests=120, max_rate=64.0)
    wall = time.perf_counter() - t0
    rows = []
    for dc, g in sorted(res.scored, key=lambda x: -x[1])[:6]:
        rows.append((f"fig11_hetero/{dc.name}", 0.0,
                     f"goodput={g:.1f};best={dc is res.disagg}"))
    rows.append(("fig11_hetero/search", wall * 1e6,
                 f"best={res.disagg.name};goodput={res.goodput:.1f};"
                 f"sims={res.n_sims};pruned={res.n_pruned};"
                 f"wall_s={wall:.1f}"))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--hetero", action="store_true",
                    help="also sweep the heterogeneous 4xH800+4xL40S cluster")
    emit(run(hetero=ap.parse_args().hetero))
