"""Paper Fig 12: the optimal disaggregation method as a function of the
TTFT / TPOT SLO pair — no single method wins everywhere."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.metrics import slo_attainment
from repro.core.request import SLO
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests

MODEL = "llava-next-7b"
METHODS = {
    "EPD": [DisaggConfig({"EPD": 8})],
    "EP+D": [DisaggConfig({"EP": k, "D": 8 - k}) for k in (2, 4, 6)],
    "ED+P": [DisaggConfig({"ED": k, "P": 8 - k}) for k in (2, 4, 6)],
    "E+P+D": [DisaggConfig({"E": 1, "P": p, "D": 7 - p}) for p in (2, 3, 4)],
}
RATES = (8.0, 16.0, 24.0, 32.0, 48.0)


def _goodput(cfg, ds, disagg, slo, img_tokens):
    best = 0.0
    for rate in RATES:
        reqs = make_requests(PROFILES[ds], rate=rate, n=100,
                             image_tokens_per_image=img_tokens, slo=slo,
                             seed=0)
        cl = Cluster(cfg, H800, disagg, slo)
        done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 120)
        if slo_attainment(done) >= 0.9:
            best = rate
        else:
            break
    return best


def run(datasets=("textcaps", "pope")):
    rows = []
    cfg = get_config(MODEL)
    img = IMAGE_TOKENS[MODEL]
    for ds in datasets:
        for ttft in (0.35, 1.0, 8.0):
            for tpot in (0.04, 0.08, 0.2):
                slo = SLO(ttft, tpot)
                best_m, best_g = None, -1.0
                for m, cands in METHODS.items():
                    g = max(_goodput(cfg, ds, dc, slo, img) for dc in cands)
                    if g > best_g:
                        best_m, best_g = m, g
                rows.append((f"fig12/{ds}/ttft{ttft}_tpot{tpot}", 0.0,
                             f"best_method={best_m};goodput_rps={best_g:.0f}"))
    return rows
