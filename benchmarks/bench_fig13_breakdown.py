"""Paper Fig 13: request-lifecycle latency breakdown (LLaVA-1.5-7B,
TextCaps, 1E3P4D) — decode dominates; migration <1%."""
from __future__ import annotations

from collections import defaultdict

from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests, slo_for

MODEL = "llava-1.5-7b"


def run():
    rows = []
    cfg = get_config(MODEL)
    slo = slo_for(MODEL, "textcaps")
    reqs = make_requests(PROFILES["textcaps"], rate=24.0, n=200,
                         image_tokens_per_image=IMAGE_TOKENS[MODEL],
                         slo=slo, seed=1)
    cl = Cluster(cfg, H800, DisaggConfig({"E": 1, "P": 3, "D": 4}), slo)
    done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 180)

    agg = defaultdict(float)
    for r in done:
        # queueing per stage = first exec start - previous stage end/arrival
        first = {}
        last_end = {}
        for name, t0, t1 in r.stage_log:
            first.setdefault(name, t0)
            last_end[name] = t1
            agg[name] += t1 - t0
        if "encode_exec" in first:
            agg["encode_queue"] += first["encode_exec"] - r.arrival
            if "prefill_exec" in first:
                agg["prefill_queue"] += max(
                    first["prefill_exec"] - last_end["encode_exec"], 0.0)
        elif "prefill_exec" in first:
            agg["prefill_queue"] += first["prefill_exec"] - r.arrival
    n = max(len(done), 1)
    total = sum(agg.values())
    for name in sorted(agg):
        ms = agg[name] / n * 1e3
        rows.append((f"fig13/{name}", ms * 1e3,
                     f"avg_ms={ms:.2f};share={agg[name]/total*100:.1f}%"))
    mig_share = agg.get("migrate", 0.0) / total * 100
    rows.append(("fig13/migration_share", 0.0,
                 f"{mig_share:.2f}% (paper: <1%)"))
    return rows
