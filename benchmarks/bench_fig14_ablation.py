"""Paper Fig 14 ablation: hybrid-EPD + stage-level scheduling (full) vs
8 general-purpose instances with stage-level scheduling (no hybrid EPD) vs
8 general-purpose instances without stage-level scheduling (decode-first).

Paper: goodput 9.5 -> 7.2 -> 5.1 req/s; we validate the strict ordering
full > stage-only > neither.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.metrics import slo_attainment
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests, slo_for

MODEL = "llava-next-7b"
RATES = (4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0, 96.0, 128.0, 160.0,
         192.0, 256.0)
HYDRA_CANDS = (DisaggConfig({"EP": 4, "D": 4}), DisaggConfig({"ED": 4, "P": 4}),
               DisaggConfig({"E": 1, "P": 3, "D": 4}))


def _goodput(cfg, disagg, policy, slo, img):
    best = 0.0
    for rate in RATES:
        reqs = make_requests(PROFILES["textcaps"], rate=rate, n=120,
                             image_tokens_per_image=img, slo=slo, seed=0)
        cl = Cluster(cfg, H800, disagg, slo, policy_name=policy)
        done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 120)
        if slo_attainment(done) >= 0.9:
            best = rate
        else:
            break
    return best


def run():
    cfg = get_config(MODEL)
    slo = slo_for(MODEL, "textcaps")
    img = IMAGE_TOKENS[MODEL]
    g_full = max(_goodput(cfg, dc, "hydra", slo, img) for dc in HYDRA_CANDS)
    g_stage = _goodput(cfg, DisaggConfig({"EPD": 8}), "hydra", slo, img)
    g_none = _goodput(cfg, DisaggConfig({"EPD": 8}), "decode_first", slo, img)
    ordering = "ok" if g_full >= g_stage >= g_none else "VIOLATED"
    return [
        ("fig14/full_hybrid_epd", 0.0, f"goodput_rps={g_full:.1f}"),
        ("fig14/stage_level_only", 0.0, f"goodput_rps={g_stage:.1f}"),
        ("fig14/no_stage_level", 0.0, f"goodput_rps={g_none:.1f}"),
        ("fig14/ordering", 0.0,
         f"{ordering} (paper: 9.5 > 7.2 > 5.1 req/s ordering)"),
    ]
