"""Paper Fig 4: per-GPU throughput of encode+decode, sequential vs parallel.

Analytical roofline over batch size (H800, LLaVA-1.5-7B, decode KV len 1024,
as in the paper) + a real-execution micro on the reduced model comparing
two separate jitted calls vs the fused joint step (the TPU analogue of two
CUDA streams).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import timeit
from repro.configs import get_config
from repro.core.costmodel import H800, BatchWork, batch_time


def run():
    rows = []
    cfg = get_config("llava-1.5-7b")
    for bs in (1, 2, 4, 8, 16, 32):
        w = BatchWork(decode_batch=64, decode_context=1024, encode_images=bs)
        t_seq = batch_time(cfg, H800, w, parallel_streams=False)
        t_par = batch_time(cfg, H800, w, parallel_streams=True)
        # per-GPU throughput: images/s while also decoding 64 streams
        rows.append((f"fig4/analytic/seq/imgs{bs}", t_seq * 1e6,
                     f"img_per_s={bs / t_seq:.1f}"))
        rows.append((f"fig4/analytic/par/imgs{bs}", t_par * 1e6,
                     f"img_per_s={bs / t_par:.1f};speedup={t_seq / t_par:.2f}x"))

    # real micro (reduced model, CPU): fused joint step vs sequential calls
    from repro.core.simulator import DisaggConfig
    from repro.engine.server import HydraServer
    from repro.engine.runner import ModelRunner, RunnerCaches
    from repro.models import model as M

    rcfg = cfg.reduced()
    params = M.init_params(rcfg, jax.random.PRNGKey(0))
    caches = RunnerCaches(rcfg, kv_blocks=256, img_blocks=8)
    runner = ModelRunner(rcfg, params, caches)
    rng = np.random.default_rng(0)
    # set up 2 decoding requests
    for rid in range(2):
        toks = rng.integers(0, rcfg.vocab_size, 12).astype(np.int32)
        runner.prefill_chunk(rid, toks)
    media = [(10, (rng.standard_normal((rcfg.media_tokens, rcfg.d_model))
                   * 0.1).astype(np.float32))]

    def seq():
        runner.encode(media)
        runner.decode([0, 1], np.array([3, 4]))
        caches.img.free(10)

    def joint():
        runner.joint_encode_decode(media, [0, 1], np.array([3, 4]))
        caches.img.free(10)

    t_seq = timeit(seq, iters=5)
    t_joint = timeit(joint, iters=5)
    rows.append(("fig4/real/sequential", t_seq, "reduced-model CPU micro"))
    rows.append(("fig4/real/joint", t_joint,
                 f"speedup={t_seq / max(t_joint, 1e-9):.2f}x (1-core CPU; "
                 "overlap benefit shows on real TPU)"))
    return rows
