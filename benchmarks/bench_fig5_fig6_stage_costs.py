"""Paper Fig 5 (arithmetic intensity vs token count / image batch) and
Fig 6 (per-stage throughput vs batch size, saturation points)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.costmodel import H800, BatchWork, batch_time, stage_cost


def run():
    rows = []
    cfg = get_config("llava-1.5-7b")

    # Fig 5: arithmetic intensity of the joint (encode+LM) batch
    for n_img in (0, 1, 4, 16):
        for toks in (1, 64, 1024, 4096):
            fe = be = 0.0
            if n_img:
                fe, be = stage_cost(cfg, "encode", n_images=n_img)
            fl, bl = stage_cost(cfg, "prefill", n_tokens=toks, batch=1,
                                context=toks)
            ai = (fe + fl) / max(be + bl, 1)
            rows.append((f"fig5/ai/imgs{n_img}_toks{toks}", 0.0,
                         f"arith_intensity={ai:.1f}"))

    # Fig 6: stage throughput vs batch size -> saturation
    sat = {}
    for stage, batches in (("encode", (1, 2, 4, 6, 8, 16, 32)),
                           ("prefill", (1, 2, 4, 8)),
                           ("decode", (1, 16, 64, 128, 256, 512, 1024))):
        prev = None
        for bs in batches:
            if stage == "encode":
                w = BatchWork(encode_images=bs)
                unit = bs
            elif stage == "prefill":
                w = BatchWork(prefill_tokens=1024 * bs, prefill_batch=bs,
                              prefill_context=1024)
                unit = 1024 * bs
            else:
                w = BatchWork(decode_batch=bs, decode_context=1024)
                unit = bs
            t = batch_time(cfg, H800, w)
            thr = unit / t
            rows.append((f"fig6/{stage}/bs{bs}", t * 1e6,
                         f"throughput={thr:.1f}/s"))
            if prev is not None and thr < prev * 1.10 and stage not in sat:
                sat[stage] = bs
            prev = thr
    rows.append(("fig6/saturation", 0.0,
                 f"encode~{sat.get('encode', '>32')} prefill~"
                 f"{sat.get('prefill', 1)} decode~{sat.get('decode', '>512')} "
                 "(paper: ~6 / 1 / ~512)"))
    return rows
