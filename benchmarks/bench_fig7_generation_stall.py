"""Paper Fig 7: the generation-stall problem across scheduling strategies.

Two requests (A, B) are decoding when two multimodal requests (C, D)
arrive; we measure the worst token-to-token gap A/B experience under each
policy on one colocated instance.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.metrics import quantile
from repro.core.request import Request, SLO
from repro.core.simulator import Cluster, DisaggConfig, Simulator


def run():
    rows = []
    cfg = get_config("llava-next-7b")
    slo = SLO(8.0, 0.08)
    for policy in ("prefill_first", "decode_first", "sarathi", "hydra"):
        reqs = []
        # A, B: text-only, long decodes, arrive first
        for rid in range(2):
            reqs.append(Request(rid=rid, arrival=0.0, n_images=0,
                                image_tokens=0, prompt_tokens=64,
                                max_new_tokens=120, slo=slo))
        # C, D: multimodal, arrive while A/B decode
        for rid in (2, 3):
            reqs.append(Request(rid=rid, arrival=0.25, n_images=1,
                                image_tokens=2880, prompt_tokens=64,
                                max_new_tokens=32, slo=slo))
        cl = Cluster(cfg, H800, DisaggConfig({"EPD": 1}), slo,
                     policy_name=policy)
        done = Simulator(cl).run(reqs, until=600.0)
        ab = [r for r in done if r.rid < 2]
        gaps = [g for r in ab for g in r.tpots()]
        stall = max(gaps) if gaps else float("nan")
        p50 = quantile(gaps, 0.5)
        rows.append((f"fig7/{policy}", stall * 1e6,
                     f"max_tpot_ms={stall*1e3:.1f};p50_tpot_ms={p50*1e3:.1f}"))
    return rows
