"""Microbenchmarks for the Pallas kernels (interpret mode on CPU — these
validate dispatch overhead/correctness here; real perf numbers need TPU)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.kernels.cache_write.ops import cache_write
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.selective_scan.ops import selective_scan


def run():
    rows = []
    rng = np.random.default_rng(0)

    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    us = timeit(lambda: flash_attention(q, k, k, interpret=True)
                .block_until_ready(), iters=3)
    us_ref = timeit(lambda: flash_attention(q, k, k, use_kernel=False)
                    .block_until_ready(), iters=3)
    rows.append(("kernels/flash_attention/interp", us, f"ref_us={us_ref:.0f}"))

    qd = jnp.asarray(rng.standard_normal((2, 4, 64)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((16, 16, 2, 64)), jnp.float32)
    bt = jnp.asarray(rng.permutation(16)[:8].reshape(2, 4), jnp.int32)
    ln = jnp.asarray([50, 60], jnp.int32)
    us = timeit(lambda: paged_attention(qd, kp, kp, bt, ln, interpret=True)
                .block_until_ready(), iters=3)
    rows.append(("kernels/paged_attention/interp", us, "decode q=1"))

    new = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    slots = jnp.asarray([3, 17, 40, 100], jnp.int32)
    # cache is donated -> fresh buffer per call
    us = timeit(lambda: cache_write(jnp.zeros((8, 16, 128), jnp.float32),
                                    new, slots, interpret=True)
                .block_until_ready(), iters=3)
    rows.append(("kernels/cache_write/interp", us, "fused KV+image write"))

    dt = jnp.asarray(np.abs(rng.standard_normal((1, 64, 64))) * 0.1)
    x = jnp.asarray(rng.standard_normal((1, 64, 64)))
    A = jnp.asarray(-np.abs(rng.standard_normal((64, 8))), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((1, 64, 8)))
    us = timeit(lambda: selective_scan(dt, x, A, Bm, Bm, interpret=True,
                                       block_d=64, chunk=32)[0]
                .block_until_ready(), iters=3)
    rows.append(("kernels/selective_scan/interp", us, "mamba1 recurrence"))
    return rows
