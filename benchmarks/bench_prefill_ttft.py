"""End-to-end engine prefill throughput / TTFT: dense vs device-paged.

Drives ``HydraServer`` (encode + prefill + decode, reduced LLaVA-1.5-7B,
single EPD instance) with the same B=8 multimodal workload under each
prefill backend:

  dense            : the seed path (``device_cache=False``) — one request
                     per Python-loop iteration, a full host gather of the
                     prior context per chunk, dense attention, a numpy
                     round-trip of every layer's chunk K/V back into the
                     cache, and a retrace for each novel (chunk, context)
                     shape
  paged-interpret  : the batched device-resident path (DESIGN.md §12) —
                     ONE jitted ``prefill_chunk_paged`` per scheduler
                     iteration over all requests' chunks, Pallas chunked
                     paged-attention + fused chunk cache-write in interpret
                     mode (the CPU default), pow2-bucketed batch/chunk/page
                     shapes so steady state never recompiles
  paged-ref        : same batched paged semantics through the pure-jnp
                     oracles (``REPRO_PAGED_IMPL=ref``), the fastest CPU
                     option

Each server is warmed with a *different* random workload first: the paged
buckets are workload-independent, while the dense path keeps its
production behavior of retracing along the novel (chunk, context)
trajectory.  Only prefill runner calls are timed (wall clock around
``ModelRunner.prefill_chunks`` / the dense ``prefill_chunk``); prefilled
tokens include media tokens entering the LM stream.  Mean/P90 TTFT over
the measured run ride along for the SLO story (they include decode time
for requests that interleave).  Results land in ``BENCH_prefill.json`` at
the repo root; the acceptance bar is paged-interpret >= 3x dense prefill
tokens/s at B=8.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import numpy as np

B = 8                # concurrent requests (acceptance point)
PROMPT_LO, PROMPT_HI = 24, 49   # text tokens (+ 16 media tokens in the LM)
MAX_NEW = 4          # a little decode so TTFT interleaving is realistic


class _PrefillTimer:
    """Wraps a runner's batched prefill entry point, accumulating wall
    time.  The dense server path goes through ``prefill_chunks`` too (the
    host fallback loops per request inside it), so one wrapper covers both
    backends."""

    def __init__(self, runner):
        self.seconds = 0.0
        self._chunks = runner.prefill_chunks
        runner.prefill_chunks = self._timed_chunks

    def _timed_chunks(self, items, *a, **kw):
        t0 = time.perf_counter()
        out = self._chunks(items, *a, **kw)
        self.seconds += time.perf_counter() - t0
        return out


def _submit_batch(srv, cfg, rng):
    for _ in range(B):
        n = int(rng.integers(PROMPT_LO, PROMPT_HI))
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                 * 0.1).astype(np.float32)
        srv.submit(prompt, media=media, max_new_tokens=MAX_NEW)


def _drive(device_cache: bool):
    from repro.configs import get_config
    from repro.core.simulator import DisaggConfig
    from repro.engine.server import HydraServer
    from repro.models import model as M

    cfg = get_config("llava-1.5-7b").reduced()
    if "p" not in _drive._params:
        _drive._params["p"] = M.init_params(cfg, jax.random.PRNGKey(0))
    params = _drive._params["p"]
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}),
                      device_cache=device_cache, kv_blocks=64)
    # warm on a different random workload (paged buckets are
    # workload-independent; dense keeps retracing in the measured run)
    _submit_batch(srv, cfg, np.random.default_rng(1))
    srv.run()
    warm_rids = set(srv.items)
    timers = [_PrefillTimer(i.runner) for i in srv.instances]
    _submit_batch(srv, cfg, np.random.default_rng(0))
    out = srv.run()
    secs = sum(t.seconds for t in timers)
    # every token that entered the LM prefill stream this measured run
    # (media + text; warm-up requests are excluded)
    meas = [r.req for rid, r in out.items() if rid not in warm_rids]
    toks = sum(r.prefill_total for r in meas
               if r.first_token_time is not None)
    ttfts = sorted(r.ttft() for r in meas if r.ttft() is not None)
    ttft_mean = float(np.mean(ttfts)) if ttfts else 0.0
    ttft_p90 = float(ttfts[int(0.9 * (len(ttfts) - 1))]) if ttfts else 0.0
    return toks / max(secs, 1e-12), toks, ttft_mean, ttft_p90


_drive._params = {}


def run(out=None):
    rows = []
    results = {}
    variants = [("dense", False, None),
                ("paged-interpret", True, "interpret"),
                ("paged-ref", True, "ref")]
    if jax.default_backend() == "tpu":
        variants.append(("paged-kernel", True, "kernel"))
    for name, device_cache, impl in variants:
        prev = os.environ.pop("REPRO_PAGED_IMPL", None)
        if impl:
            os.environ["REPRO_PAGED_IMPL"] = impl
        try:
            tok_per_s, toks, ttft_mean, ttft_p90 = _drive(device_cache)
        finally:
            os.environ.pop("REPRO_PAGED_IMPL", None)
            if prev:
                os.environ["REPRO_PAGED_IMPL"] = prev
        results[name] = {"prefill_tokens_per_s": tok_per_s,
                         "prefill_tokens": toks, "batch": B,
                         "ttft_mean_s": ttft_mean, "ttft_p90_s": ttft_p90}
        rows.append((f"engine/prefill/{name}", 1e6 / max(tok_per_s, 1e-12),
                     f"tok_per_s={tok_per_s:.1f} ttft_p90={ttft_p90:.3f}s"))
    speedup = (results["paged-interpret"]["prefill_tokens_per_s"]
               / results["dense"]["prefill_tokens_per_s"])
    results["speedup"] = speedup
    results["backend"] = jax.default_backend()
    if out is None:
        out = Path(__file__).resolve().parent.parent / "BENCH_prefill.json"
    Path(out).write_text(json.dumps(results, indent=2) + "\n")
    rows.append(("engine/prefill/speedup", 0.0, f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
