"""Open-loop serving SLO benchmark: P90 TTFT/TPOT attainment + goodput.

The real-execution analogue of the simulator's Fig-10 goodput sweep
(``bench_fig10_goodput``): requests arrive as an open-loop Poisson process
(arrival times drawn up front, submitted on the wall clock — NOT closed
loop) into a live streaming ``Engine`` (DESIGN.md §13) running the hydra
policy on a single EPD instance, reduced LLaVA-1.5-7B, device-resident
paged caches with fused on-device sampling.  Because ``Engine.submit`` is
legal while the loop runs, late requests join mid-flight and experience
real queueing — exactly the regime the paper's P90 SLO claims are about.

Metrics per request come from the ``Request`` lifecycle timestamps (TTFT,
TPOT list, ``meets_slo`` — paper §2.3 definitions) and aggregate through
``core.metrics.summarize``.  Goodput here is SLO-met requests/s over the
measured horizon.  Results land in ``BENCH_serving.json`` at the repo root.

A warmup pass with the *same* request shapes (same rng seed) pre-compiles
every pow2 jit bucket, so the measured pass sees steady-state step times —
compile stalls would otherwise dominate TTFT on CPU.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

# knobs (smoke tests monkeypatch these down)
N = 12               # measured requests
RATE = 3.0           # Poisson arrival rate, requests/s
MAX_NEW = 8
PROMPT_LO, PROMPT_HI = 8, 20
P_IMAGE = 0.5        # fraction of requests carrying an image
SLO_TTFT = 2.5       # seconds (CPU-scale SLO)
SLO_TPOT = 0.25      # seconds/token
KV_BLOCKS = 96

_params_cache: dict = {}


def _requests(cfg, seed: int):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(N):
        n = int(rng.integers(PROMPT_LO, PROMPT_HI))
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        media = None
        if rng.random() < P_IMAGE:
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        out.append((prompt, media))
    gaps = rng.exponential(1.0 / RATE, size=N)
    return out, np.cumsum(gaps)


def _submit_all(engine, bodies, arrivals):
    """Submit ``bodies`` at their Poisson ``arrivals`` (None = as fast as
    possible), returning rids.  Blocks until all finish."""
    from repro.core.request import SamplingParams

    t0 = time.monotonic()
    rids = []
    for i, (prompt, media) in enumerate(bodies):
        if arrivals is not None:
            lag = arrivals[i] - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
        rids.append(engine.submit(
            prompt, media=media, sampling=SamplingParams(max_tokens=MAX_NEW)))
    if not engine.wait(rids, timeout=600.0):
        raise RuntimeError("serving bench timed out")
    return rids, time.monotonic() - t0


def _drive():
    import jax

    from repro.configs import get_config
    from repro.core.request import SLO
    from repro.core.simulator import DisaggConfig
    from repro.engine.api import Engine
    from repro.models import model as M

    cfg = get_config("llava-1.5-7b").reduced()
    if "p" not in _params_cache:
        _params_cache["p"] = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, _params_cache["p"], DisaggConfig({"EPD": 1}),
                    slo=SLO(SLO_TTFT, SLO_TPOT), kv_blocks=KV_BLOCKS)
    bodies, arrivals = _requests(cfg, seed=0)  # same shapes warm + measured
    engine.start()
    try:
        # warmup on the SAME engine (jits are per-ModelRunner, so a fresh
        # engine would recompile): one closed-loop pass compiles the large
        # batch buckets, one Poisson-timed pass compiles the small-batch
        # buckets the measured trajectory actually visits
        _submit_all(engine, bodies, arrivals=None)
        _submit_all(engine, bodies, arrivals)
        rids, horizon = _submit_all(engine, bodies, arrivals)
    finally:
        engine.close()
    return [engine.result(r).req for r in rids], horizon


def run(out=None):
    from repro.core.metrics import summarize

    reqs, horizon = _drive()
    s = summarize(reqs, RATE, horizon)
    met = sum(1 for r in reqs if r.meets_slo())
    results = {
        "n_requests": len(reqs),
        "rate_rps": RATE,
        "horizon_s": horizon,
        "p50_ttft_s": s.p50_ttft,
        "p90_ttft_s": s.p90_ttft,
        "p50_tpot_s": s.p50_tpot,
        "p90_tpot_s": s.p90_tpot,
        "slo": {"ttft_s": SLO_TTFT, "tpot_s": SLO_TPOT},
        "attainment": s.attainment,
        "goodput_rps": met / horizon if horizon else 0.0,
        "tokens_per_s": s.tokens_per_s,
    }
    import jax
    results["backend"] = jax.default_backend()
    if out is None:
        out = Path(__file__).resolve().parent.parent / "BENCH_serving.json"
    Path(out).write_text(json.dumps(results, indent=2) + "\n")
    return [
        ("serving/p90_ttft", s.p90_ttft * 1e6, f"p90_ttft={s.p90_ttft:.3f}s"),
        ("serving/p90_tpot", s.p90_tpot * 1e6,
         f"p90_tpot={s.p90_tpot*1e3:.1f}ms"),
        ("serving/attainment", 0.0, f"attainment={s.attainment:.2%}"),
        ("serving/goodput", 0.0,
         f"goodput_rps={results['goodput_rps']:.2f}"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
