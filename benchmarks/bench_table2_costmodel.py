"""Paper Table 2 validation: the analytical FLOPs model vs XLA's own
cost_analysis on the compiled reduced model (CPU, 1 device)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.costmodel import stage_cost
from repro.models import model as M


def run():
    rows = []
    cfg = get_config("llava-1.5-7b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64

    def fwd(params, tokens):
        return M.forward(cfg, params, tokens)[0]

    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    pspec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         params)
    compiled = jax.jit(fwd).lower(pspec, tokens).compile()
    xla_flops = compiled.cost_analysis().get("flops", 0.0)
    ana_flops, _ = stage_cost(cfg, "prefill", n_tokens=B * S, batch=B,
                              context=S)
    ratio = ana_flops / max(xla_flops, 1)
    rows.append(("table2/prefill_flops", 0.0,
                 f"analytic={ana_flops:.3e};xla={xla_flops:.3e};"
                 f"ratio={ratio:.2f} (blockwise attn computes full-S scores "
                 "-> xla >= analytic expected)"))

    cache = M.cache_specs(cfg, B, S, jnp.float32)
    tok1 = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def dec(params, cache, tok):
        return M.decode_step(cfg, params, cache, jnp.int32(S - 1), tok)

    compiled = jax.jit(dec).lower(pspec, cache, tok1).compile()
    xla_flops_d = compiled.cost_analysis().get("flops", 0.0)
    ana_flops_d, _ = stage_cost(cfg, "decode", batch=B, context=S)
    rows.append(("table2/decode_flops", 0.0,
                 f"analytic={ana_flops_d:.3e};xla={xla_flops_d:.3e};"
                 f"ratio={ana_flops_d / max(xla_flops_d, 1):.2f}"))
    return rows
