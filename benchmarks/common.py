"""Shared benchmark helpers."""
from __future__ import annotations

import time


def timeit(fn, *args, iters: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
