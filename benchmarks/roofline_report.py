"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and derives
per (arch x shape x mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF bf16, v5e)
  memory term     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective term = collective_bytes_per_device / link_bw    (~50 GB/s ICI)

plus MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (inference) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * n_devices).

Usage: PYTHONPATH=src:. python -m benchmarks.roofline_report [--csv out.csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.core.costmodel import active_param_count, param_count

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: 1 token per request


def suggestion(dom: str, row: dict) -> str:
    arch, shape = row["arch"], row["shape"]
    if dom == "collective":
        return ("reduce resharding: align cache/attention layouts or "
                "shard_map the attention so KV stays model-sharded")
    if dom == "memory":
        if "decode" in shape or shape == "long_500k":
            return ("decode is BW-bound by design; shrink cache reads "
                    "(MLA/window/quantized KV) or grow per-chip batch")
        return "increase arithmetic intensity: larger per-device batch/fusion"
    return ("compute-bound (good); next: cut redundant FLOPs "
            "(causal-aware attention blocks, remat policy)")


def load_rows():
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        t_comp = r["flops_per_device"] / PEAK_FLOPS
        t_mem = r["bytes_per_device"] / HBM_BW
        t_coll = r["collective_bytes_per_device"]["total"] / LINK_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = r["flops_per_device"] * r["n_devices"]
        rows.append({
            **r,
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dom,
            "model_flops": mf,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "peak_gb": r["memory"]["peak_bytes"] / 1e9,
            "fix": suggestion(dom, r),
        })
    return rows


def run():
    """benchmarks.run entry: emit name,us,derived rows."""
    out = []
    for r in load_rows():
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        dom_t = r[f"t_{r['dominant']}_s"]
        out.append((name, dom_t * 1e6,
                    f"dom={r['dominant']};comp_s={r['t_compute_s']:.4f};"
                    f"mem_s={r['t_memory_s']:.4f};"
                    f"coll_s={r['t_collective_s']:.4f};"
                    f"useful={r['useful_ratio']:.2f};"
                    f"peakGB={r['peak_gb']:.1f}"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None)
    ap.add_argument("--markdown", default=None)
    args = ap.parse_args()
    rows = load_rows()
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'comp_s':>9s} "
           f"{'mem_s':>9s} {'coll_s':>9s} {'dom':>10s} {'useful':>7s} "
           f"{'peakGB':>7s}")
    print(hdr)
    print("-" * len(hdr))
    lines = []
    for r in rows:
        line = (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
                f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
                f"{r['useful_ratio']:7.2f} {r['peak_gb']:7.1f}")
        print(line)
        lines.append(line)
    if args.csv:
        with open(args.csv, "w") as f:
            f.write("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
                    "dominant,useful_ratio,peak_gb,fix\n")
            for r in rows:
                f.write(f"{r['arch']},{r['shape']},{r['mesh']},"
                        f"{r['t_compute_s']:.6f},{r['t_memory_s']:.6f},"
                        f"{r['t_collective_s']:.6f},{r['dominant']},"
                        f"{r['useful_ratio']:.3f},{r['peak_gb']:.2f},"
                        f"\"{r['fix']}\"\n")
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write("| arch | shape | mesh | compute s | memory s | "
                    "collective s | dominant | useful | peak GB | next move |\n")
            f.write("|---|---|---|---|---|---|---|---|---|---|\n")
            for r in rows:
                f.write(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | "
                        f"{r['t_collective_s']:.4f} | {r['dominant']} | "
                        f"{r['useful_ratio']:.2f} | {r['peak_gb']:.1f} | "
                        f"{r['fix']} |\n")


if __name__ == "__main__":
    main()
