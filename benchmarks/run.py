"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` runs a reduced subset
(used by CI-style checks); default runs everything.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "benchmarks.bench_table2_costmodel",
    "benchmarks.bench_fig5_fig6_stage_costs",
    "benchmarks.bench_fig4_multistream",
    "benchmarks.bench_fig7_generation_stall",
    "benchmarks.bench_kernels",
    "benchmarks.bench_engine_throughput",
    "benchmarks.bench_prefill_ttft",
    "benchmarks.bench_serving_slo",
    "benchmarks.bench_cache",
    "benchmarks.bench_fault_recovery",
    "benchmarks.bench_fig13_breakdown",
    "benchmarks.bench_fig14_ablation",
    "benchmarks.bench_autotuner",
    "benchmarks.bench_fig11_node_ratio",
    "benchmarks.bench_fig12_method_vs_slo",
    "benchmarks.bench_fig10_goodput",
]
QUICK = MODULES[:11]  # original quick set + engine/serving/cache/faults


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on module names")
    args = ap.parse_args()
    mods = QUICK if args.quick else MODULES
    if args.only:
        keys = args.only.split(",")
        mods = [m for m in MODULES if any(k in m for k in keys)]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(name)
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}", flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
