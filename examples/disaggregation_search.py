"""Hybrid EPD disaggregation search (paper §4.4, DESIGN.md §7): profile a
workload + SLO and automatically pick the best disaggregation method + node
ratio with the autotuner (bound pruning + warm-started bisection + sim
caching + parallel fan-out).

Run:  PYTHONPATH=src python examples/disaggregation_search.py [dataset]
      PYTHONPATH=src python examples/disaggregation_search.py --hetero
          # heterogeneous 4xH800 + 4xL40S cluster: per-role hardware
      PYTHONPATH=src python examples/disaggregation_search.py --exhaustive
          # naive serial grid (the reference the autotuner replaces)
"""
import argparse
import time

from repro.configs import get_config
from repro.core.autotuner import (autotune_disaggregation,
                                  enumerate_hetero_disaggs)
from repro.core.costmodel import H800, L40S
from repro.core.hybrid_epd import enumerate_disaggs, search_disaggregation
from repro.data.workload import IMAGE_TOKENS, PROFILES, slo_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("dataset", nargs="?", default="textcaps",
                    choices=sorted(PROFILES))
    ap.add_argument("--hetero", action="store_true",
                    help="search a 4xH800 + 4xL40S cluster with per-role "
                         "hardware assignment")
    ap.add_argument("--exhaustive", action="store_true",
                    help="use the naive serial grid instead of the autotuner")
    ap.add_argument("--model", default="llava-next-7b")
    ap.add_argument("--max-rate", type=float, default=64.0)
    args = ap.parse_args()

    cfg = get_config(args.model)
    profile = PROFILES[args.dataset]
    slo = slo_for(args.model, args.dataset)
    img = IMAGE_TOKENS.get(args.model, cfg.media_tokens)

    if args.hetero:
        pools = [(H800, 4), (L40S, 4)]
        cands = enumerate_hetero_disaggs(pools)
        cluster = " + ".join(f"{n}x{hw.name}" for hw, n in pools)
    else:
        cands = [c for c in enumerate_disaggs(8)
                 if sum(s.count for _, s in c.roles) == 8]
        cluster = "8xH800"
    print(f"workload={args.dataset} model={args.model} SLO: TTFT<={slo.ttft}s "
          f"TPOT<={slo.tpot}s\nsearching {len(cands)} method x ratio "
          f"candidates on {cluster} ...\n")

    t0 = time.perf_counter()
    if args.exhaustive:
        res = search_disaggregation(cfg, H800, profile, slo,
                                    candidates=cands, image_tokens=img,
                                    n_requests=100, max_rate=args.max_rate)
        scored, n_sims = res.details, res.n_sims
    else:
        res = autotune_disaggregation(cfg, H800, profile, slo,
                                      candidates=cands, image_tokens=img,
                                      n_requests=100, max_rate=args.max_rate)
        scored, n_sims = res.scored, res.n_sims
    wall = time.perf_counter() - t0

    for dc, g in sorted(scored, key=lambda x: -x[1])[:10]:
        mark = " <== selected" if dc is res.disagg else ""
        print(f"  {dc.name:24s} goodput={g:6.1f} req/s{mark}")
    if not args.exhaustive:
        print(f"  (+ {res.n_pruned} candidates pruned by cost-model bounds "
              f"without simulation)")
    print(f"\nbest method: {res.disagg.method} ratio {res.disagg.name} "
          f"at {res.goodput:.1f} req/s goodput")
    print(f"search wall-clock: {wall:.1f}s, {n_sims} simulations")


if __name__ == "__main__":
    main()
