"""Hybrid EPD disaggregation search (paper §4.4): profile a workload + SLO
and automatically pick the best disaggregation method + node ratio on a
simulated 8xH800 cluster.

Run:  PYTHONPATH=src python examples/disaggregation_search.py [dataset]
"""
import sys

from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.hybrid_epd import enumerate_disaggs, search_disaggregation
from repro.data.workload import IMAGE_TOKENS, PROFILES, slo_for


def main():
    ds = sys.argv[1] if len(sys.argv) > 1 else "textcaps"
    model = "llava-next-7b"
    cfg = get_config(model)
    profile = PROFILES[ds]
    slo = slo_for(model, ds)
    print(f"workload={ds} model={model} SLO: TTFT<={slo.ttft}s "
          f"TPOT<={slo.tpot}s\nsearching methods x ratios on 8xH800 ...\n")

    # a representative candidate subset (full enumeration also works)
    cands = [c for c in enumerate_disaggs(8)
             if sum(c.counts.values()) == 8][:18]
    res = search_disaggregation(cfg, H800, profile, slo, candidates=cands,
                                image_tokens=IMAGE_TOKENS[model],
                                n_requests=100, max_rate=64.0)
    for dc, g in sorted(res.details, key=lambda x: -x[1])[:10]:
        mark = " <== selected" if dc is res.disagg else ""
        print(f"  {dc.name:12s} goodput={g:5.1f} req/s{mark}")
    print(f"\nbest method: {res.disagg.method} ratio {res.disagg.name} "
          f"at {res.goodput:.1f} req/s goodput")


if __name__ == "__main__":
    main()
