"""Quickstart: serve a small LLaVA-style MLLM with batched multimodal
requests through the full HydraInfer stack — Algorithm-1 stage-level
batching, hybrid E+P+D disaggregated instances, pull-based cache migration —
executing for real in JAX on CPU, through the **streaming engine API**
(DESIGN.md §13): requests join a live continuously-batched loop, tokens
stream back per request, and sampling runs fused on device.

(The legacy closed-loop ``HydraServer.submit()`` + ``run()`` surface still
works — see ``test_engine.py`` — but new code should use ``Engine``.)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.request import SamplingParams
from repro.core.simulator import DisaggConfig
from repro.engine.api import Engine
from repro.models import model as M


def main():
    cfg = get_config("llava-1.5-7b").reduced()
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model}, "
          f"{cfg.media_tokens} image tokens)")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # 1 encode + 1 prefill + 1 decode instance (the paper's E+P+D method)
    engine = Engine(cfg, params, DisaggConfig({"E": 1, "P": 1, "D": 1}))

    rng = np.random.default_rng(0)
    streams = []
    t0 = time.time()
    for i in range(6):
        prompt = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
        media = None
        if i % 2 == 0:  # half the requests carry an image
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        # even requests decode greedily, odd ones sample (seeded nucleus)
        sampling = SamplingParams(max_tokens=12) if i % 2 == 0 else \
            SamplingParams(temperature=0.8, top_k=50, top_p=0.95,
                           seed=1234 + i, max_tokens=12)
        streams.append(engine.generate(prompt, media=media,
                                       sampling=sampling))

    # consume request 0's stream live: iterating it DRIVES the engine, so
    # all six requests progress together (continuous batching) while the
    # first one's tokens print as they are produced
    print(f"req {streams[0].rid} streaming:", end=" ", flush=True)
    for ev in streams[0]:
        if ev.kind == "finish":
            print(f"[{ev.finish_reason}]")
        else:
            print(ev.token, end=" ", flush=True)

    # drain the rest (already partially or fully decoded by now)
    for st in streams[1:]:
        st.tokens()
    dt = time.time() - t0

    srv = engine.server
    for st in streams:
        item = engine.result(st.rid)
        kind = "multimodal" if item.media is not None else "text-only"
        mode = "greedy" if (item.req.sampling.temperature <= 0) else "sampled"
        print(f"req {st.rid} ({kind}, {mode}): {item.generated}")
    toks = sum(len(engine.result(s.rid).generated) for s in streams)
    print(f"\n{len(streams)} requests, {toks} tokens in {dt:.1f}s; "
          f"{srv.n_migrations} migrations moved "
          f"{srv.migrated_bytes/1e6:.1f} MB "
          f"(E->P image cache, P->D KV cache)")


if __name__ == "__main__":
    main()
