"""Quickstart: serve a small LLaVA-style MLLM with batched multimodal
requests through the full HydraInfer stack — Algorithm-1 stage-level
batching, hybrid E+P+D disaggregated instances, pull-based cache migration —
executing for real in JAX on CPU.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.simulator import DisaggConfig
from repro.engine.server import HydraServer
from repro.models import model as M


def main():
    cfg = get_config("llava-1.5-7b").reduced()
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model}, "
          f"{cfg.media_tokens} image tokens)")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # 1 encode + 1 prefill + 1 decode instance (the paper's E+P+D method)
    server = HydraServer(cfg, params, DisaggConfig({"E": 1, "P": 1, "D": 1}))

    rng = np.random.default_rng(0)
    rids = []
    t0 = time.time()
    for i in range(6):
        prompt = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
        media = None
        if i % 2 == 0:  # half the requests carry an image
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        rids.append(server.submit(prompt, media=media, max_new_tokens=12))

    out = server.run()
    dt = time.time() - t0
    for rid in rids:
        item = out[rid]
        kind = "multimodal" if item.media is not None else "text-only"
        print(f"req {rid} ({kind}): {item.generated}")
    toks = sum(len(out[r].generated) for r in rids)
    print(f"\n{len(rids)} requests, {toks} tokens in {dt:.1f}s; "
          f"{server.n_migrations} migrations moved "
          f"{server.migrated_bytes/1e6:.1f} MB "
          f"(E->P image cache, P->D KV cache)")


if __name__ == "__main__":
    main()
