"""Generation-stall demo (paper Fig 7): watch two decoding requests stall —
or not — when multimodal requests arrive, under the four scheduling
policies.

Run:  PYTHONPATH=src python examples/stage_level_batching.py
"""
from repro.configs import get_config
from repro.core.costmodel import H800
from repro.core.metrics import quantile
from repro.core.request import Request, SLO
from repro.core.simulator import Cluster, DisaggConfig, Simulator


def main():
    cfg = get_config("llava-next-7b")
    slo = SLO(8.0, 0.08)
    print("2 requests decoding; 2 multimodal requests arrive at t=0.25s.")
    print("max token-to-token gap of the decoding requests:\n")
    for policy in ("prefill_first", "decode_first", "sarathi", "hydra"):
        reqs = [Request(rid=i, arrival=0.0, n_images=0, image_tokens=0,
                        prompt_tokens=64, max_new_tokens=120, slo=slo)
                for i in range(2)]
        reqs += [Request(rid=i, arrival=0.25, n_images=1, image_tokens=2880,
                         prompt_tokens=64, max_new_tokens=32, slo=slo)
                 for i in (2, 3)]
        cl = Cluster(cfg, H800, DisaggConfig({"EPD": 1}), slo,
                     policy_name=policy)
        done = Simulator(cl).run(reqs, until=600)
        gaps = [g for r in done if r.rid < 2 for g in r.tpots()]
        print(f"  {policy:14s} max={max(gaps)*1e3:7.1f} ms   "
              f"p50={quantile(gaps, .5)*1e3:5.1f} ms   "
              f"({'STALL' if max(gaps) > 4 * quantile(gaps, .5) else 'smooth'})")
    print("\nhydra (Algorithm 1) keeps decodes running: encode is a separate")
    print("stage executed in the parallel stream, prefill is chunked within")
    print("the profiled token budget.")


if __name__ == "__main__":
    main()
