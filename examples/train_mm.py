"""End-to-end training driver: train a ~100M-param reduced multimodal model
for a few hundred steps on synthetic packed data with AdamW + cosine LR +
checkpointing.

Run:  PYTHONPATH=src python examples/train_mm.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batches
from repro.train.optim import AdamWConfig, init_opt_state
from repro.train.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llava-1.5-7b")
    ap.add_argument("--ckpt", default="/tmp/repro_train_mm.npz")
    args = ap.parse_args()

    # ~100M-param variant: reduced depth/width but a real vocab
    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              num_layers=4, d_model=512, num_heads=8,
                              num_kv_heads=8, head_dim=64, d_ff=1536,
                              vocab_size=32000, media_tokens=64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}-mini: {n_params/1e6:.1f}M params")

    opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt))
    data = batches(cfg, DataConfig(batch_size=4, seq_len=128))

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, stats = step(params, state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(stats['loss']):.3f}  "
                  f"lr {float(stats['lr']):.2e}  "
                  f"gnorm {float(stats['grad_norm']):.2f}  "
                  f"{(i+1)/(time.time()-t0):.2f} it/s")
    ckpt.save(args.ckpt, {"params": params, "opt": state})
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
