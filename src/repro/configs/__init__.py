"""Architecture registry: the 10 assigned configs + the paper's own model."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ATTN_MLP, ATTN_MOE, MLA_MLP, MLA_MOE, MAMBA1, MAMBA2, SHARED_ATTN,
    INPUT_SHAPES, InputShape, ModelConfig, input_specs, shape_applicable,
)

_ARCH_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-small": "whisper_small",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama3-8b": "llama3_8b",
    "gemma3-4b": "gemma3_4b",
    "zamba2-7b": "zamba2_7b",
    "stablelm-12b": "stablelm_12b",
    "pixtral-12b": "pixtral_12b",
    "gemma-7b": "gemma_7b",
    # the paper's own evaluation models
    "llava-1.5-7b": "llava15_7b",
    "llava-next-7b": "llava_next_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
}

PAPER_MODELS = ["llava-1.5-7b", "llava-next-7b", "qwen2-vl-7b"]
ASSIGNED_ARCHS = [a for a in _ARCH_MODULES if a not in PAPER_MODELS]
ALL_ARCHS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ALL_ARCHS)
