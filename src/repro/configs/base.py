"""Model / input-shape configuration for the HydraInfer reproduction.

Every assigned architecture gets a ``ModelConfig`` with the exact numbers
from the assignment table, plus a ``reduced()`` variant used by CPU smoke
tests (2 layers, d_model<=512, <=4 experts).  ``input_specs`` builds
ShapeDtypeStruct stand-ins (no allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------
ATTN_MLP = "attn_mlp"          # dense attention + (gated) MLP
ATTN_MOE = "attn_moe"          # dense attention + MoE FFN
MLA_MLP = "mla_mlp"            # multi-head latent attention + dense MLP
MLA_MOE = "mla_moe"            # multi-head latent attention + MoE FFN
MAMBA1 = "mamba1"              # Mamba-1 selective-scan block
MAMBA2 = "mamba2"              # Mamba-2 (SSD) block
SHARED_ATTN = "shared_attn"    # Zamba-style shared attention+MLP block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    act: str = "silu"           # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (0 -> d_ff)
    first_dense_layers: int = 0  # leading layers with dense FFN (deepseek)
    moe_capacity_factor: float = 1.25  # train/prefill token-drop capacity

    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- SSM ---
    ssm_state: int = 0
    d_inner: int = 0            # 0 -> 2 * d_model
    conv_kernel: int = 4
    dt_rank: int = 0            # 0 -> d_model // 16
    mamba2_head_dim: int = 64

    # --- hybrid (zamba) ---
    attn_every: int = 0         # every Nth layer is a SHARED_ATTN block

    # --- sliding window (gemma3) ---
    sliding_window: int = 0
    global_every: int = 0       # 1 global attention layer per N (others local)

    # --- modality frontend (stub per assignment carve-out) ---
    frontend: str = "none"      # none | vision | audio
    media_tokens: int = 0       # tokens contributed by one media item
    encoder_layers: int = 0     # whisper encoder depth (enc-dec only)
    cross_attention: bool = False
    # analytical vision-tower profile (cost model only; the tower is a stub)
    vision_layers: int = 0
    vision_d_model: int = 0

    source: str = ""            # citation from the assignment table

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("ssm", "hybrid"):
            if self.d_inner == 0:
                object.__setattr__(self, "d_inner", 2 * self.d_model)
            if self.dt_rank == 0:
                object.__setattr__(self, "dt_rank", max(1, self.d_model // 16))

    # ------------------------------------------------------------------
    def layer_kinds(self) -> list[str]:
        """Per-layer block kind, index 0 .. num_layers-1."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append(MAMBA1)
            elif self.family == "hybrid":
                if self.attn_every and (i % self.attn_every) == (self.attn_every - 1):
                    kinds.append(SHARED_ATTN)
                else:
                    kinds.append(MAMBA2)
            elif self.num_experts > 0:
                if self.kv_lora_rank > 0:
                    kinds.append(MLA_MLP if i < self.first_dense_layers else MLA_MOE)
                else:
                    kinds.append(ATTN_MOE)
            else:
                kinds.append(ATTN_MLP)
        return kinds

    def is_local_layer(self, i: int) -> bool:
        """Sliding-window (local) attention layer?  gemma3: 5 local : 1 global."""
        if not self.sliding_window or not self.global_every:
            return False
        return (i % self.global_every) != (self.global_every - 1)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        if self.family in ("ssm", "hybrid"):
            return True
        # Sliding-window dense archs qualify: only the sparse global layers
        # hold full-length KV.
        return bool(self.sliding_window and self.global_every)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decoder (whisper is enc-dec)

    @property
    def kv_dim(self) -> int:
        """Flattened per-token KV width for one of K or V."""
        if self.kv_lora_rank:  # MLA compressed cache: latent + shared rope key
            return self.kv_lora_rank + self.qk_rope_head_dim
        return self.num_kv_heads * self.head_dim

    @property
    def n_media(self) -> int:
        return self.media_tokens

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        upd = dict(
            name=self.name + "-reduced",
            num_layers=2 if self.attn_every == 0 else 2 * self.attn_every,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=64,
            d_ff=max(64, min(self.d_ff, 512)),
            vocab_size=min(self.vocab_size, 512),
            d_inner=0,
            dt_rank=0,
        )
        if self.num_experts:
            upd.update(num_experts=4, experts_per_token=min(2, self.experts_per_token),
                       num_shared_experts=min(1, self.num_shared_experts),
                       moe_d_ff=128, first_dense_layers=min(1, self.first_dense_layers))
        if self.kv_lora_rank:
            upd.update(kv_lora_rank=64, q_lora_rank=64,
                       qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        if self.sliding_window:
            upd.update(sliding_window=16, global_every=2)
        if self.media_tokens:
            upd.update(media_tokens=16)
        if self.encoder_layers:
            upd.update(encoder_layers=2)
        if self.attn_every:
            # keep hybrid structure: 2*attn_every layers -> 2 shared-attn uses
            upd.update(attn_every=min(self.attn_every, 3),
                       num_layers=2 * min(self.attn_every, 3))
        cfg = replace(self, **upd)
        return cfg


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) must be lowered; (ok, reason-if-skip)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch; long_500k needs sub-quadratic attention"
    return True, ""


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input stand-ins (dry-run; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct inputs for the step function selected by shape.kind.

    train/prefill: {tokens, (labels), (media)} where len(media)+len(tokens)
    == seq_len.  decode: {token, cache_len}; the KV/state cache specs come
    from models.cache_specs (they depend on layer kinds).
    """
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    bf16 = jnp.bfloat16
    specs: dict = {}
    # Vision media is a decoder-sequence prefix (LLaVA-style interleave);
    # audio frames feed cross-attention instead (whisper enc-dec).
    n_media = cfg.media_tokens if cfg.frontend == "vision" else 0
    if shape.kind in ("train", "prefill"):
        n_media_eff = min(n_media, max(0, S - 16))
        s_text = S - n_media_eff
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
        if n_media_eff:
            specs["media"] = jax.ShapeDtypeStruct((B, n_media_eff, cfg.d_model), bf16)
        if cfg.cross_attention:
            # whisper: decoder cross-attends to encoder frames
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.media_tokens, cfg.d_model), bf16)
    else:  # decode: one new token against a cache of S tokens
        specs["token"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["cache_len"] = jax.ShapeDtypeStruct((), i32)
    return specs
