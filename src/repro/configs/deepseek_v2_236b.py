"""deepseek-v2-236b — MLA (kv_lora=512) + MoE 160e top-6 + 2 shared [arXiv:2405.04434]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,       # nominal; MLA compresses the cache to kv_lora+rope
    head_dim=128,
    d_ff=12288,             # dense FFN of the first layer
    vocab_size=102400,
    num_experts=160,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    source="arXiv:2405.04434",
)
