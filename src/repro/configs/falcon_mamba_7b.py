"""falcon-mamba-7b — attention-free Mamba-1 SSM [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,           # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                # Mamba block subsumes the FFN
    vocab_size=65024,
    ssm_state=16,
    conv_kernel=4,
    source="arXiv:2410.05355",
)
