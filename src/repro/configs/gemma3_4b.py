"""gemma3-4b — 5:1 local:global sliding-window attention [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,         # 5 local : 1 global
    act="gelu",             # GeGLU
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
