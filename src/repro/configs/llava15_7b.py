"""llava-1.5-7b — the paper's evaluation model: CLIP-ViT-L/336 (stub, 576
image tokens) + Vicuna-7B (llama-architecture) backbone [arXiv:2304.08485]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-1.5-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    frontend="vision",
    media_tokens=576,       # 336x336 / 14x14 patches (paper: 576 tokens/image)
    vision_layers=24,
    vision_d_model=1024,
    source="arXiv:2304.08485 (paper's own eval model)",
)
