"""llava-next-7b — paper eval model; high-res tiling -> ~2880 image tokens
[arXiv:2407.07895]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32064,
    frontend="vision",
    media_tokens=2880,      # AnyRes tiling: base + 4 tiles x 576
    vision_layers=24,
    vision_d_model=1024,
    source="arXiv:2407.07895 (paper's own eval model)",
)
