"""pixtral-12b — pixtral-ViT (stub) + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    frontend="vision",
    media_tokens=1024,      # patch embeddings per image (stubbed ViT)
    vision_layers=24,
    vision_d_model=1024,
    source="hf:mistralai/Pixtral-12B-2409",
)
