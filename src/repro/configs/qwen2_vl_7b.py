"""qwen2-vl-7b — paper eval model; resolution-adaptive visual tokens
[arXiv:2409.12191]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    frontend="vision",
    media_tokens=1236,      # ~typical for dataset images
    vision_layers=32,
    vision_d_model=1280,
    source="arXiv:2409.12191 (paper's own eval model)",
)
