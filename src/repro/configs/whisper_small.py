"""whisper-small — enc-dec audio; conv/mel frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,          # decoder depth
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,        # GQA kv=12 (i.e. MHA)
    d_ff=3072,
    vocab_size=51865,
    act="gelu_mlp",         # plain (non-gated) GELU MLP, as in whisper
    frontend="audio",
    media_tokens=1500,      # precomputed mel+conv frame embeddings
    cross_attention=True,
    rope_theta=0.0,         # whisper uses learned absolute positions
    source="arXiv:2212.04356",
)
