"""zamba2-7b — Mamba-2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    mamba2_head_dim=64,
    attn_every=6,           # every 6th layer is the shared attention block
    source="arXiv:2411.15242",
)
