"""HydraInfer core: the paper's scheduling system (DESIGN.md §1).

  request         - E/P/D request lifecycle + SLO accounting (§1.2, §8)
  costmodel       - Table-2 FLOPs/bytes + roofline + hardware profiles (§2)
  simulator       - discrete-event cluster simulator, pull-based
                    migration, heterogeneous DisaggConfig (§3, §4, §7.2)
  batch_scheduler - Algorithm-1 stage-level batching + baselines (§5)
  budgets         - TPOT-constrained token/image budget profiling (§6)
  hybrid_epd      - exhaustive disaggregation search (§7)
  autotuner       - pruned/warm-started/cached/parallel search (§7.1)
  metrics         - TTFT/TPOT/attainment/goodput (§8)
"""
