"""Disaggregation autotuner (DESIGN.md §7): fast profile-driven search for
the goodput-maximizing disaggregation method + instance ratio, including
heterogeneous clusters where each role group runs on its own hardware.

Replaces the naive serial grid of ``hybrid_epd.search_disaggregation``
(every candidate scored with a full goodput bisection) with four
optimizations that preserve the argmax:

  1. cost-model upper bounds — a candidate's goodput can never exceed the
     aggregate per-stage service capacity of its instances (roofline, no
     queueing/interference), so candidates whose bound falls below the best
     goodput found so far are pruned without a single simulation;
  2. warm-started bisection — candidates are visited in descending-bound
     order and each bisection brackets around the incumbent best rate
     instead of restarting from the full [lo, max_rate] interval;
  3. simulation caching — results are memoized on (disagg, rate, seed, …)
     with probe rates quantized to the bisection tolerance grid;
  4. ``concurrent.futures`` fan-out — surviving candidates are evaluated in
     waves of worker threads, with pruning re-applied between waves.  Note
     the simulator is pure Python, so on CPython the threads are GIL-bound:
     the measured speedup comes from (1)-(3) running *fewer* simulations,
     not from parallelism; the wave structure exists so a free-threaded or
     subinterpreter runtime can exploit it.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core.costmodel import BatchWork, Hardware, batch_time
from repro.core.request import SLO, Stage
from repro.core.simulator import ROLE_SETS, DisaggConfig, RoleSpec
from repro.data.workload import WorkloadProfile


# ---------------------------------------------------------------------------
# candidate enumeration (heterogeneous)
# ---------------------------------------------------------------------------
def _compositions(n: int, k: int):
    """All ways to write n = c_1 + ... + c_k with every c_i >= 1."""
    if k == 1:
        yield (n,)
        return
    for first in range(1, n - k + 2):
        for rest in _compositions(n - first, k - 1):
            yield (first,) + rest


def enumerate_hetero_disaggs(pools, *, multimodal: bool = True,
                             methods: Optional[list] = None
                             ) -> list[DisaggConfig]:
    """Enumerate disaggregations over a heterogeneous cluster.

    ``pools`` is a list of ``(Hardware, count)`` device pools.  Each role
    group of a method (e.g. ``EP`` and ``D`` for method ``EP+D``) is pinned
    to exactly one pool; groups sharing a pool split its devices in every
    ratio; every device of every pool is used.  This is the paper-relevant
    shape (encode/prefill on compute-heavy chips vs decode on
    bandwidth-heavy ones) without the combinatorial blowup of per-instance
    assignment.
    """
    methods = methods or (["EP+D", "ED+P", "E+P+D"] if multimodal
                          else ["P+D"])
    out, seen = [], set()
    for method in methods:
        groups = method.split("+")
        if len(groups) < 2 and len(pools) > 1:
            continue  # a single group cannot span two hardware types
        for assign in itertools.product(range(len(pools)),
                                        repeat=len(groups)):
            if set(assign) != set(range(len(pools))):
                continue  # use every pool
            per_pool = {p: [g for g, a in zip(groups, assign) if a == p]
                        for p in range(len(pools))}
            if any(len(gs) > pools[p][1] for p, gs in per_pool.items()):
                continue  # more groups than devices in the pool
            splits = [list(_compositions(pools[p][1], len(gs)))
                      for p, gs in per_pool.items() if gs]
            pool_ids = [p for p, gs in per_pool.items() if gs]
            for combo in itertools.product(*splits):
                counts = {}
                for p, split in zip(pool_ids, combo):
                    hw = pools[p][0]
                    for g, c in zip(per_pool[p], split):
                        counts[g] = RoleSpec(count=c, hw=hw)
                dc = DisaggConfig(counts)
                if dc.name not in seen:
                    seen.add(dc.name)
                    out.append(dc)
    return out


# ---------------------------------------------------------------------------
# cost-model goodput upper bound
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class WorkloadStats:
    """Mean per-request work, estimated by sampling the profile."""
    prefill_tokens: float
    decode_tokens: float
    images: float
    decode_context: float


def workload_stats(profile: WorkloadProfile, image_tokens_per_image: int,
                   *, n: int = 512, seed: int = 0,
                   cache=None) -> WorkloadStats:
    """Mean per-request stage work sampled from the profile.  ``cache``
    (a ``costmodel.CacheFeedback``) discounts prefill tokens and encode
    images by their measured hit rates — decode context is NOT discounted:
    cache-adopted pages are still read every decode step."""
    rng = np.random.default_rng(seed)
    pre, dec, img = [], [], []
    for _ in range(n):
        n_img, prompt, out = profile.sample_lengths(rng)
        pre.append(prompt + n_img * image_tokens_per_image)
        dec.append(out)
        img.append(n_img)
    pre_m, dec_m = float(np.mean(pre)), float(np.mean(dec))
    img_m = float(np.mean(img))
    ctx = pre_m + dec_m / 2
    if cache is not None:
        pre_m = cache.effective_prefill(pre_m)
        img_m = cache.effective_images(img_m)
    return WorkloadStats(prefill_tokens=pre_m, decode_tokens=dec_m,
                         images=img_m, decode_context=ctx)


def _stage_rate(cfg: ModelConfig, hw: Hardware, tp: int, stage: Stage,
                stats: WorkloadStats) -> float:
    """Best-case requests/s one instance can serve for one stage.

    Evaluated at large, efficiency-friendly batch compositions, so it upper
    bounds what the simulator (finite batches, mixed work, queueing) attains.
    """
    if stage == Stage.ENCODE:
        if stats.images <= 0:
            return float("inf")
        B = 64
        t = batch_time(cfg, hw, BatchWork(encode_images=B), tp=tp)
        return B / t / stats.images
    if stage == Stage.PREFILL:
        N = 8192
        t = batch_time(cfg, hw, BatchWork(
            prefill_tokens=N, prefill_batch=4,
            prefill_context=max(1, int(stats.prefill_tokens))), tp=tp)
        return N / t / stats.prefill_tokens
    # decode: bandwidth-bound; rate grows with batch toward an asymptote
    B = 1024
    ctx = max(1, int(stats.decode_context))
    t = batch_time(cfg, hw, BatchWork(decode_batch=B, decode_context=ctx),
                   tp=tp)
    return B / t / stats.decode_tokens


def _horizon_corrected(cap_rate: float, ttft_slack: float,
                       n_requests: int) -> float:
    """Finite-horizon TTFT bound for a work-conserving stage.

    With ``n`` requests arriving at rate ``r``, the k-th request's
    time-to-first-token satisfies TTFT_k >= k * (1/cap - 1/r) (total work
    k/cap processed at aggregate capacity, arrival at k/r).  Attainment
    >= 90% forces the 0.9n-th request under the TTFT SLO, so

        r <= 1 / (1/cap - ttft / (0.9 n))

    and the stage is unconstrained over this horizon when the right-hand
    denominator is non-positive (the queue never outlives the SLO slack).
    """
    if cap_rate <= 0:
        return 0.0
    inv = 1.0 / cap_rate - ttft_slack / (0.9 * n_requests)
    return float("inf") if inv <= 0 else 1.0 / inv


def _decode_batch_cap(cfg: ModelConfig, hw: Hardware, tp: int,
                      stats: WorkloadStats) -> int:
    """Max concurrent decodes one instance admits (KV-capacity bound),
    mirroring ``Instance.__init__``'s capacity computation."""
    per_tok = max(cm.kv_bytes_per_token(cfg), 1)
    weight_bytes = cm.active_param_count(cfg) * cm.BYTES
    free = max(hw.mem_bytes * tp * 0.9 - weight_bytes, per_tok * 4096)
    per_req = (stats.prefill_tokens + stats.decode_tokens) * per_tok
    return max(1, int(free / max(per_req, 1)))


def _decode_bound(cfg: ModelConfig, hw_default: Hardware,
                  disagg: DisaggConfig, stats: WorkloadStats, slo: SLO, *,
                  n_requests: int, tp: int, slack: float) -> float:
    """TPOT-side upper bound on goodput over the simulated horizon.

    A finished request fails its TPOT SLO only if >10% of its token gaps
    exceed the budget, and a gap is one decode iteration at the current
    batch size.  Admission control caps that batch at the KV capacity, so:
    if some decode group's capped pile-up batch still iterates within the
    TPOT budget, decode cannot produce violations at all (requests queue —
    harming only TTFT, which the prefill bound already covers) and the
    stage is unconstrained.  Otherwise the pile-up stays below the largest
    TPOT-compliant batch B* only while the arrival rate is at most the
    aggregate service rate at B*.
    """
    ctx = max(1, int(stats.decode_context))
    dec_groups = [(s.hw if s.hw is not None else hw_default,
                   s.tp if s.tp is not None else tp, s.count)
                  for role, s in disagg.roles
                  if Stage.DECODE in ROLE_SETS[role]]
    if not dec_groups:
        return 0.0           # nothing can decode: no request ever finishes
    n_dec = sum(c for _, _, c in dec_groups)
    rate = 0.0
    for hw, itp, count in dec_groups:
        b_eff = min(_decode_batch_cap(cfg, hw, itp, stats),
                    max(1, -(-n_requests // n_dec)))
        def t_iter(b):
            return batch_time(cfg, hw, BatchWork(decode_batch=b,
                                                 decode_context=ctx), tp=itp)

        if t_iter(b_eff) <= slo.tpot:
            return float("inf")
        # largest TPOT-compliant batch B* (t_iter is monotone in batch);
        # service rate peaks there since b/t_iter(b) is increasing
        lo, hi = 1, b_eff
        if t_iter(lo) > slo.tpot:
            continue                 # even B=1 violates TPOT: contributes 0
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if t_iter(mid) <= slo.tpot:
                lo = mid
            else:
                hi = mid - 1
        rate += count * (lo / t_iter(lo)) / stats.decode_tokens
    return rate * slack


def upper_bound_goodput(cfg: ModelConfig, hw_default: Hardware,
                        disagg: DisaggConfig, stats: WorkloadStats,
                        slo: SLO, *, n_requests: int, tp: int = 1,
                        slack: float = 1.25) -> float:
    """Upper bound on a candidate's simulated goodput.

    Encode/prefill are TTFT-bound: aggregate roofline capacity with the
    finite-horizon correction of :func:`_horizon_corrected`.  Decode is
    TPOT-bound: see :func:`_decode_bound`.  ``slack`` inflates the capacity
    estimates so cost-model vs simulator discrepancy never prunes the true
    argmax.
    """
    cap = {Stage.ENCODE: 0.0, Stage.PREFILL: 0.0}
    for role, s in disagg.roles:
        hw = s.hw if s.hw is not None else hw_default
        itp = s.tp if s.tp is not None else tp
        for stage in ROLE_SETS[role]:
            # shared-role instances are granted to each stage in full —
            # generous, but that is what keeps this a true upper bound
            if stage in cap:
                cap[stage] += s.count * _stage_rate(cfg, hw, itp, stage,
                                                    stats)
    bounds = [_horizon_corrected(cap[Stage.PREFILL] * slack, slo.ttft,
                                 n_requests)]
    if stats.images > 0:
        bounds.append(_horizon_corrected(cap[Stage.ENCODE] * slack,
                                         slo.ttft, n_requests))
    bounds.append(_decode_bound(cfg, hw_default, disagg, stats, slo,
                                n_requests=n_requests, tp=tp, slack=slack))
    return min(bounds)


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------
@dataclass
class CandidateResult:
    disagg: DisaggConfig
    bound: float
    goodput: Optional[float]      # None if pruned without simulation
    pruned: bool


@dataclass
class AutotuneResult:
    disagg: DisaggConfig
    goodput: float
    details: list                 # [CandidateResult], bound-descending
    n_sims: int                   # simulator invocations actually run
    n_pruned: int
    wall_s: float

    @property
    def scored(self) -> list:
        """(DisaggConfig, goodput) pairs, naive-search-compatible."""
        return [(c.disagg, c.goodput) for c in self.details
                if c.goodput is not None]


class _SimCache:
    """Memoized, counted attainment probes; thread-safe."""

    def __init__(self, simulate):
        self._simulate = simulate
        self._cache: dict = {}
        self._lock = threading.Lock()
        self.n_sims = 0

    def attain(self, disagg: DisaggConfig, rate: float) -> float:
        key = (disagg.name, rate)
        with self._lock:
            if key in self._cache:
                return self._cache[key]
        val = self._simulate(disagg, rate)
        with self._lock:
            if key not in self._cache:
                self._cache[key] = val
                self.n_sims += 1
        return self._cache[key]


def _quantize(rate: float, tol: float) -> float:
    return max(tol, round(rate / tol) * tol)


def _bisect_goodput(attain, *, hi_cap: float, guess: Optional[float],
                    target: float, tol: float,
                    lo_floor: float = 0.25) -> float:
    """Goodput bisection on the tol-grid with a warm-start first probe.

    ``attain(rate) -> attainment``; returns the largest grid rate observed
    to meet ``target`` (0.0 if none).  The first probe lands on the warm
    guess, so a candidate no better than the incumbent is typically
    rejected after a single simulation; a candidate that cannot even serve
    ``lo_floor`` is rejected after two.
    """
    lo, hi = 0.0, _quantize(hi_cap, tol) + tol
    probe = _quantize(min(guess, hi_cap) if guess else hi_cap, tol)
    first = True
    while hi - lo > tol:
        if not (lo < probe < hi):
            probe = _quantize((lo + hi) / 2, tol)
            if not (lo < probe < hi):
                break
        if attain(probe) >= target:
            lo = probe
        else:
            hi = probe
            if first and hi > lo_floor >= tol:
                fl = _quantize(lo_floor, tol)
                if attain(fl) < target:
                    return 0.0   # dead: fails even at the floor rate
                lo = fl
        first = False
        probe = _quantize((lo + hi) / 2, tol)
    return lo


def autotune_disaggregation(cfg: ModelConfig, hw: Hardware,
                            profile: WorkloadProfile, slo: SLO, *,
                            n_gpus: int = 8, policy: str = "hydra",
                            n_requests: int = 120,
                            candidates: Optional[list] = None,
                            image_tokens: Optional[int] = None,
                            max_rate: float = 64.0, target: float = 0.9,
                            tol: float = 0.125, bound_slack: float = 1.25,
                            max_workers: int = 4, tp: int = 1,
                            seed: int = 0, cache=None) -> AutotuneResult:
    """Bound-pruned, warm-started, cached, fanned-out disaggregation search.

    Drop-in accelerator for ``hybrid_epd.search_disaggregation``: same
    candidate space and simulator, same argmax (bound pruning only discards
    candidates provably below the incumbent), far fewer simulations.
    """
    from repro.core.hybrid_epd import enumerate_disaggs, simulate_once

    t0 = time.perf_counter()
    multimodal = profile.p_image > 0
    cands = candidates or enumerate_disaggs(n_gpus, multimodal=multimodal)
    img = image_tokens if image_tokens is not None else cfg.media_tokens
    # measured cache hit rates tilt the stage-rate bounds: prefix hits
    # shrink prefill work, encode hits shrink encode work (DESIGN.md §14)
    stats = workload_stats(profile, img, seed=seed, cache=cache)

    def simulate(disagg, rate):
        s, _, _ = simulate_once(cfg, hw, disagg, profile, slo, rate=rate,
                                n_requests=n_requests, policy=policy,
                                image_tokens=image_tokens, seed=seed, tp=tp)
        return s.attainment

    cache = _SimCache(simulate)
    bounds = [(dc, min(max_rate,
                       upper_bound_goodput(cfg, hw, dc, stats, slo,
                                           n_requests=n_requests, tp=tp,
                                           slack=bound_slack)))
              for dc in cands]
    bounds.sort(key=lambda x: -x[1])

    results: dict = {}
    best_g, best_dc = 0.0, bounds[0][0]

    def evaluate(dc, bound, guess):
        g = _bisect_goodput(lambda r: cache.attain(dc, r),
                            hi_cap=bound, guess=guess, target=target, tol=tol)
        return dc, bound, g

    # incumbent first (highest bound), then waves of surviving candidates
    dc0, b0 = bounds[0]
    _, _, g0 = evaluate(dc0, b0, None)
    results[dc0.name] = CandidateResult(dc0, b0, g0, pruned=False)
    if g0 > best_g:
        best_g, best_dc = g0, dc0

    rest = bounds[1:]
    with ThreadPoolExecutor(max_workers=max_workers) as ex:
        for i in range(0, len(rest), max_workers):
            wave = rest[i:i + max_workers]
            live = []
            for dc, b in wave:
                if b <= best_g:
                    results[dc.name] = CandidateResult(dc, b, None,
                                                       pruned=True)
                else:
                    live.append((dc, b))
            futs = [ex.submit(evaluate, dc, b, best_g or None)
                    for dc, b in live]
            for f in futs:
                dc, b, g = f.result()
                results[dc.name] = CandidateResult(dc, b, g, pruned=False)
                if g > best_g:
                    best_g, best_dc = g, dc

    details = [results[dc.name] for dc, _ in bounds]
    return AutotuneResult(disagg=best_dc, goodput=best_g, details=details,
                          n_sims=cache.n_sims,
                          n_pruned=sum(1 for c in details if c.pruned),
                          wall_s=time.perf_counter() - t0)
