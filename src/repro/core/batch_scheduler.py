"""Stage-level batching — paper Algorithm 1, DESIGN.md §5 — plus the
baseline scheduling policies it is evaluated against (Figs 7, 10, 14).

Policies:
  hydra          : Algorithm 1 — all ongoing decodes, then chunked prefill
                   within the token budget, else encode within the image
                   budget; migrate tasks always ride along.  Encode runs in
                   a parallel stream (fused joint step on TPU).
  prefill_first  : vLLM-v0-style FCFS — whole encode+prefill of new requests
                   preempts decoding (generation stall).
  decode_first   : vLLM-v1-style — decodes always run; new requests join
                   with their full (unchunked) encode+prefill in the same
                   batch.
  sarathi        : chunked prefill with a token budget, but encode is NOT a
                   separate stage: the iteration whose chunk covers the
                   image region triggers the full image encode inline
                   (sequential stream) — the paper's Takeaway-3 suboptimality.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.budgets import Budgets
from repro.core.request import Request, Stage


@dataclass
class Batch:
    decode: list = field(default_factory=list)            # [Request]
    prefill: list = field(default_factory=list)           # [(Request, chunk)]
    encode: list = field(default_factory=list)            # [(Request, n_images)]
    inline_encode: bool = False                            # sarathi-style stall

    @property
    def empty(self) -> bool:
        return not (self.decode or self.prefill or self.encode)


def _ready(r: Request, now: float) -> bool:
    return r.ready_at <= now + 1e-12


class Policy:
    name = "base"
    parallel_streams = True

    def build(self, inst, now: float) -> Batch:
        raise NotImplementedError


class HydraPolicy(Policy):
    """Paper Algorithm 1."""
    name = "hydra"
    parallel_streams = True

    def build(self, inst, now: float) -> Batch:
        b = Batch()
        tau_t = inst.budgets.token_budget
        tau_e = inst.budgets.image_budget
        n_t = 0
        n_e = 0
        has_prefill = False

        # 1. all ongoing decodes (admitting migrated-in decode requests
        #    first: admission triggers the pull-based cache transfer)
        if Stage.DECODE in inst.role:
            while inst.pop_waiting(Stage.DECODE, now) is not None:
                pass
            for r in inst.running:
                if r.stage == Stage.DECODE and _ready(r, now):
                    b.decode.append(r)
                    n_t += 1

        # 2. ongoing chunked prefills within the token budget
        if Stage.PREFILL in inst.role:
            for r in inst.running:
                if r.stage == Stage.PREFILL and _ready(r, now) and n_t < tau_t:
                    chunk = min(r.prefill_remaining, tau_t - n_t)
                    if chunk > 0:
                        b.prefill.append((r, chunk))
                        n_t += chunk
                        has_prefill = True
            # 3. admit new prefill-ready requests within the budget
            while n_t < tau_t:
                r = inst.pop_waiting(Stage.PREFILL, now)
                if r is None:
                    break
                if not _ready(r, now):
                    continue  # pull still in flight; it is in running now
                chunk = min(r.prefill_remaining, tau_t - n_t)
                b.prefill.append((r, chunk))
                n_t += chunk
                has_prefill = True

        # 4. encode only when no prefill work was scheduled
        if Stage.ENCODE in inst.role and not has_prefill:
            for r in inst.running:
                if r.stage == Stage.ENCODE and _ready(r, now) and n_e < tau_e:
                    b.encode.append((r, r.n_images))
                    n_e += r.n_images
            while n_e < tau_e:
                r = inst.pop_waiting(Stage.ENCODE, now)
                if r is None:
                    break
                if not _ready(r, now):
                    continue
                b.encode.append((r, r.n_images))
                n_e += r.n_images
        return b


class PrefillFirstPolicy(Policy):
    """vLLM-v0 style: FCFS, whole prefill (+ inline encode) first."""
    name = "prefill_first"
    parallel_streams = False

    def build(self, inst, now: float) -> Batch:
        b = Batch()
        # any request needing encode/prefill preempts decoding entirely
        new_work = [r for r in inst.running
                    if r.stage in (Stage.ENCODE, Stage.PREFILL) and _ready(r, now)]
        while True:
            r = inst.pop_waiting(None, now)
            if r is None:
                break
            if _ready(r, now):
                new_work.append(r)
        if new_work:
            for r in new_work[:64]:
                if r.stage == Stage.ENCODE:
                    b.encode.append((r, r.n_images))
                    b.inline_encode = True
                    # encode+full prefill execute back-to-back this iteration
                    b.prefill.append((r, r.prefill_total))
                else:
                    b.prefill.append((r, r.prefill_remaining))
            return b
        for r in inst.running:
            if r.stage == Stage.DECODE and _ready(r, now):
                b.decode.append(r)
        return b


class DecodeFirstPolicy(Policy):
    """vLLM-v1 style: decodes always run; new requests join with unchunked
    encode+prefill in the same batch."""
    name = "decode_first"
    parallel_streams = False

    def build(self, inst, now: float) -> Batch:
        b = Batch()
        for r in inst.running:
            if r.stage == Stage.DECODE and _ready(r, now):
                b.decode.append(r)
        admitted = 0
        for r in list(inst.running):
            if admitted >= 4:
                break
            if r.stage in (Stage.ENCODE, Stage.PREFILL) and _ready(r, now):
                if r.stage == Stage.ENCODE:
                    b.encode.append((r, r.n_images))
                    b.inline_encode = True
                    b.prefill.append((r, r.prefill_total))
                else:
                    b.prefill.append((r, r.prefill_remaining))
                admitted += 1
        while admitted < 4:
            r = inst.pop_waiting(None, now)
            if r is None:
                break
            if not _ready(r, now):
                continue
            if r.stage == Stage.ENCODE:
                b.encode.append((r, r.n_images))
                b.inline_encode = True
                b.prefill.append((r, r.prefill_total))
            else:
                b.prefill.append((r, r.prefill_remaining))
            admitted += 1
        return b


class SarathiPolicy(Policy):
    """Chunked prefill + stall-free decode mixing, but encode inline: the
    chunk that reaches the image region triggers the full encode within the
    same (sequential-stream) iteration."""
    name = "sarathi"
    parallel_streams = False

    def build(self, inst, now: float) -> Batch:
        b = Batch()
        tau_t = inst.budgets.token_budget
        n_t = 0
        for r in inst.running:
            if r.stage == Stage.DECODE and _ready(r, now):
                b.decode.append(r)
                n_t += 1

        def add_prefill(r):
            nonlocal n_t
            # encode not yet done and the chunk covers the image region ->
            # the full image encode happens inline this iteration
            if r.stage == Stage.ENCODE:
                b.encode.append((r, r.n_images))
                b.inline_encode = True
                r_chunk = min(r.prefill_remaining, max(tau_t - n_t, 16))
                b.prefill.append((r, r_chunk))
                n_t += r_chunk
            else:
                chunk = min(r.prefill_remaining, tau_t - n_t)
                if chunk > 0:
                    b.prefill.append((r, chunk))
                    n_t += chunk

        for r in inst.running:
            if r.stage in (Stage.PREFILL, Stage.ENCODE) and _ready(r, now) \
                    and n_t < tau_t:
                add_prefill(r)
        while n_t < tau_t:
            r = inst.pop_waiting(None, now)
            if r is None:
                break
            if _ready(r, now):
                add_prefill(r)
        return b


POLICIES = {p.name: p for p in (HydraPolicy(), PrefillFirstPolicy(),
                                DecodeFirstPolicy(), SarathiPolicy())}
