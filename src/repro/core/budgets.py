"""Budget profiling (paper §4.2, DESIGN.md §6): binary-search the max
prefill token budget and encode image budget such that one batch iteration
stays under the TPOT SLO even with a full complement of ongoing decodes in
the batch.  Heterogeneous clusters profile one ``Budgets`` per distinct
(Hardware, TP) pair — see DESIGN.md §7.2."""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.costmodel import BatchWork, Hardware, batch_time


@dataclass(frozen=True)
class Budgets:
    token_budget: int    # tau_t: chunked-prefill tokens per iteration
    image_budget: int    # tau_e: images encoded per iteration


def _iter_time(cfg, hw, *, prefill_tokens=0, images=0, decode_batch=0,
               decode_context=1024, tp=1):
    work = BatchWork(decode_batch=decode_batch, decode_context=decode_context,
                     prefill_tokens=prefill_tokens, prefill_batch=1,
                     prefill_context=prefill_tokens, encode_images=images)
    return batch_time(cfg, hw, work, parallel_streams=True, tp=tp)


def _bsearch(lo: int, hi: int, ok) -> int:
    """Largest x in [lo, hi] with ok(x); lo-1 if none."""
    if not ok(lo):
        return lo - 1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if ok(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def compute_budgets(cfg: ModelConfig, hw: Hardware, tpot_slo: float, *,
                    ref_decode_batch: int = 64, ref_context: int = 1024,
                    tp: int = 1, max_tokens: int = 16384,
                    max_images: int = 64) -> Budgets:
    """Profile tau_t and tau_e by binary search (paper Algorithm 1 init)."""
    def tok_ok(n):
        return _iter_time(cfg, hw, prefill_tokens=n,
                          decode_batch=ref_decode_batch,
                          decode_context=ref_context, tp=tp) <= tpot_slo

    def img_ok(n):
        return _iter_time(cfg, hw, images=n, decode_batch=ref_decode_batch,
                          decode_context=ref_context, tp=tp) <= tpot_slo

    tau_t = max(_bsearch(1, max_tokens, tok_ok), 16)    # floor: progress guarantee
    tau_e = max(_bsearch(1, max_images, img_ok), 1)
    return Budgets(token_budget=tau_t, image_budget=tau_e)
