"""Analytical stage cost model (paper Table 1/2, DESIGN.md §2) + hardware
profiles.

Per-stage FLOPs and memory traffic for encode / prefill / decode, evaluated
against a roofline ``T = max(T_comp, T_mem)`` (paper §3.1, [39]).  The model
drives (a) the discrete-event simulator's batch execution times (DESIGN.md
§3), (b) the budget binary search of Algorithm 1 (DESIGN.md §6), (c) the
Fig-5/Fig-6 benchmarks, and (d) the autotuner's goodput upper bounds
(DESIGN.md §7).

The paper's key "multi-stream" observation falls out naturally: for a batch
that mixes encode work (compute-leaning) and decode work (memory-bound),

  sequential:  T = max(Ce, Me) + max(Cd, Md)
  parallel:    T = max(Ce + Cd, Me + Md)        (two streams / fused step)

so parallel execution hides the idle side of each roofline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.configs.base import (ATTN_MLP, ATTN_MOE, MLA_MLP, MLA_MOE, MAMBA1,
                                MAMBA2, SHARED_ATTN, ModelConfig)


# ---------------------------------------------------------------------------
# hardware
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # dense bf16/fp16 FLOP/s per chip
    hbm_bw: float              # B/s per chip
    link_bw: float             # B/s inter-chip (migration path)
    mem_bytes: float           # HBM capacity per chip
    mfu: float = 0.60          # achievable fraction of peak flops
    mbu: float = 0.80          # achievable fraction of peak bandwidth
    kernel_overhead: float = 40e-6  # per-op launch/dispatch overhead (s)
    # serving calibration: real engines see distinct efficiencies per stage
    # (ViT encode is small-matmul-bound; decode is bandwidth-bound) plus a
    # per-iteration scheduler/launch overhead (Python + ~1e2 kernels).
    encode_mfu: float = 0.20
    prefill_mfu: float = 0.55
    serve_mbu: float = 0.60
    iter_overhead: float = 2.5e-3


H800 = Hardware("H800", peak_flops=989e12, hbm_bw=3.35e12, link_bw=400e9,
                mem_bytes=80e9)
A100 = Hardware("A100", peak_flops=312e12, hbm_bw=2.04e12, link_bw=300e9,
                mem_bytes=80e9)
L40S = Hardware("L40S", peak_flops=362e12, hbm_bw=864e9, link_bw=64e9,
                mem_bytes=48e9)
TPU_V5E = Hardware("TPUv5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
                   mem_bytes=16e9, iter_overhead=1.5e-3)
CPU_SIM = Hardware("CPUsim", peak_flops=200e9, hbm_bw=20e9, link_bw=10e9,
                   mem_bytes=8e9, kernel_overhead=1e-3, iter_overhead=20e-3)

HARDWARE = {"h800": H800, "a100": A100, "l40s": L40S, "v5e": TPU_V5E,
            "cpu": CPU_SIM}

BYTES = 2  # fp16/bf16 (paper: all weights/caches fp16)


# ---------------------------------------------------------------------------
# per-model static quantities
# ---------------------------------------------------------------------------
def _attn_like(kind) -> bool:
    return kind in (ATTN_MLP, ATTN_MOE, MLA_MLP, MLA_MOE, SHARED_ATTN)


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (weights actually stored)."""
    d, H, Kh, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    shared_counted = False
    for kind in cfg.layer_kinds():
        if kind in (ATTN_MLP, ATTN_MOE):
            total += d * (H * Dh) * 2 + d * (Kh * Dh) * 2
            if cfg.cross_attention:
                total += d * (H * Dh) * 2 + d * (Kh * Dh) * 2
        elif kind in (MLA_MLP, MLA_MOE):
            ql = cfg.q_lora_rank or d
            total += d * ql + ql * H * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
            total += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            total += cfg.kv_lora_rank * H * (cfg.qk_nope_head_dim + cfg.v_head_dim)
            total += H * cfg.v_head_dim * d
        elif kind in (MAMBA1,):
            di = cfg.d_inner
            total += d * 2 * di + di * (cfg.dt_rank + 2 * cfg.ssm_state)
            total += cfg.dt_rank * di + di * cfg.ssm_state + di * d
        elif kind == MAMBA2:
            di = cfg.d_inner
            total += d * 2 * di + d * 2 * cfg.ssm_state + di * d
        elif kind == SHARED_ATTN and not shared_counted:
            total += d * (H * Dh) * 2 + d * (Kh * Dh) * 2 + 3 * d * cfg.d_ff
            shared_counted = True
        # FFN
        if kind in (ATTN_MLP, MLA_MLP):
            n_mats = 2 if cfg.act == "gelu_mlp" else 3
            total += n_mats * d * cfg.d_ff
        elif kind in (ATTN_MOE, MLA_MOE):
            ff = cfg.moe_d_ff or cfg.d_ff
            total += d * cfg.num_experts + 3 * cfg.num_experts * d * ff
            total += 3 * d * ff * cfg.num_shared_experts
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (4 * d * d + 2 * d * cfg.d_ff)
    if cfg.frontend == "vision":
        total += 4 * cfg.d_model ** 2  # projector
        total += cfg.vision_layers * 12 * cfg.vision_d_model ** 2  # tower (stub)
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: only routed top-k experts)."""
    if not cfg.num_experts:
        return param_count(cfg)
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    total = param_count(cfg)
    n_moe = sum(1 for k in cfg.layer_kinds() if k in (ATTN_MOE, MLA_MOE))
    total -= 3 * n_moe * d * ff * (cfg.num_experts - cfg.experts_per_token)
    return int(total)


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """KV-cache bytes per context token (all layers)."""
    total = 0
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind in (MLA_MLP, MLA_MOE):
            total += (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * BYTES
        elif _attn_like(kind):
            total += 2 * cfg.num_kv_heads * cfg.head_dim * BYTES
    return total


def ssm_state_bytes(cfg: ModelConfig, batch: int = 1) -> int:
    """Fixed-size recurrent state bytes per request (SSM/hybrid)."""
    total = 0
    for kind in cfg.layer_kinds():
        if kind == MAMBA1:
            total += cfg.d_inner * cfg.ssm_state * 4
            total += (cfg.conv_kernel - 1) * cfg.d_inner * BYTES
        elif kind == MAMBA2:
            total += cfg.d_inner * cfg.ssm_state * 4
            total += (cfg.conv_kernel - 1) * (cfg.d_inner + 2 * cfg.ssm_state) * BYTES
    return total * batch


def image_cache_bytes(cfg: ModelConfig, n_images: int = 1) -> int:
    """Image-token cache bytes per image (paper: 1-layer single-token cache)."""
    return n_images * cfg.media_tokens * cfg.d_model * BYTES


# ---------------------------------------------------------------------------
# stage FLOPs / memory traffic (paper Table 2, generalized per layer kind)
# ---------------------------------------------------------------------------
def _dense_layer_cost(d, h_q, h_kv, ff, n_tokens, context, batch, n_mats):
    """One attn+mlp layer: (flops, bytes).  n_tokens = new tokens in batch;
    context = average context length attended to (per request)."""
    # projections: q, o are d*h_q; k, v are d*h_kv; ff mats
    proj_w = 2 * d * h_q + 2 * d * h_kv + n_mats * d * ff
    flops = 2 * n_tokens * proj_w
    # attention score+value flops: tokens x context x (h_q dims) x 2 matmuls
    flops += 4 * n_tokens * context * h_q
    bytes_ = proj_w * BYTES                      # weights
    bytes_ += 2 * n_tokens * d * BYTES           # activations in/out (approx)
    bytes_ += 2 * batch * context * h_kv * BYTES  # KV read
    return flops, bytes_


def stage_cost(cfg: ModelConfig, stage: str, *, n_tokens: int = 0,
               batch: int = 1, context: int = 0, n_images: int = 0):
    """(flops, bytes) for one batch iteration of a stage.

    encode: n_images media items through the frontend (+projector).
    prefill: n_tokens new prompt tokens (sum over requests), avg ``context``.
    decode: batch requests x 1 token, avg ``context`` each.
    """
    d = cfg.d_model
    if stage == "encode":
        flops = bytes_ = 0.0
        T = cfg.media_tokens
        if cfg.frontend == "audio" or cfg.encoder_layers:
            L, dd, ff = cfg.encoder_layers, d, cfg.d_ff
            for _ in range(L):
                f, b = _dense_layer_cost(dd, dd, dd, ff, n_images * T, T, n_images, 2)
                flops += f
                bytes_ += b
        else:
            vd = cfg.vision_d_model or d
            for _ in range(cfg.vision_layers or 24):
                f, b = _dense_layer_cost(vd, vd, vd, 4 * vd, n_images * T, T,
                                         n_images, 2)
                flops += f
                bytes_ += b
            # projector
            flops += 2 * n_images * T * 4 * d * d
            bytes_ += 4 * d * d * BYTES + 2 * n_images * T * d * BYTES
        return flops, bytes_

    if stage == "decode":
        n_tokens = batch
    if context == 0:
        context = max(1, n_tokens // max(batch, 1))

    flops = bytes_ = 0.0
    h_q = cfg.num_heads * cfg.head_dim
    h_kv = cfg.num_kv_heads * cfg.head_dim
    n_mats = 2 if cfg.act == "gelu_mlp" else 3
    for kind in cfg.layer_kinds():
        if kind in (ATTN_MLP, SHARED_ATTN):
            f, b = _dense_layer_cost(d, h_q, h_kv, cfg.d_ff, n_tokens, context,
                                     batch, n_mats)
        elif kind == ATTN_MOE:
            ff = cfg.moe_d_ff or cfg.d_ff
            f, b = _dense_layer_cost(d, h_q, h_kv, 0, n_tokens, context, batch, 0)
            k_act = cfg.experts_per_token + cfg.num_shared_experts
            f += 2 * n_tokens * 3 * d * ff * k_act
            # decode touches up to min(E, batch*k) expert weight sets
            touched = min(cfg.num_experts, max(1, n_tokens) * cfg.experts_per_token)
            b += 3 * d * ff * touched * BYTES
        elif kind in (MLA_MLP, MLA_MOE):
            ql = cfg.q_lora_rank or d
            qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            H = cfg.num_heads
            R = cfg.kv_lora_rank
            proj_w = d * ql + ql * H * qk + d * (R + cfg.qk_rope_head_dim) \
                + R * H * (cfg.qk_nope_head_dim + cfg.v_head_dim) \
                + H * cfg.v_head_dim * d
            f = 2 * n_tokens * proj_w
            f += 4 * n_tokens * context * H * (R + cfg.qk_rope_head_dim) \
                if stage == "decode" else 4 * n_tokens * context * H * qk
            b = proj_w * BYTES + 2 * n_tokens * d * BYTES
            b += batch * context * (R + cfg.qk_rope_head_dim) * BYTES
            if kind == MLA_MOE:
                ff = cfg.moe_d_ff or cfg.d_ff
                k_act = cfg.experts_per_token + cfg.num_shared_experts
                f += 2 * n_tokens * 3 * d * ff * k_act
                touched = min(cfg.num_experts,
                              max(1, n_tokens) * cfg.experts_per_token)
                b += 3 * d * ff * touched * BYTES
            else:
                f += 2 * n_tokens * 3 * d * cfg.d_ff
                b += 3 * d * cfg.d_ff * BYTES
        elif kind in (MAMBA1, MAMBA2):
            di = cfg.d_inner
            N = cfg.ssm_state
            w = 2 * d * di + di * d
            if kind == MAMBA1:
                w += di * (cfg.dt_rank + 2 * N) + cfg.dt_rank * di
            f = 2 * n_tokens * w + 10 * n_tokens * di * N  # scan elementwise
            b = w * BYTES + 2 * n_tokens * d * BYTES + batch * di * N * 4
        else:
            raise ValueError(kind)
        flops += f
        bytes_ += b
    # embedding + head
    flops += 2 * n_tokens * d * cfg.vocab_size
    bytes_ += cfg.vocab_size * d * BYTES
    return flops, bytes_


# ---------------------------------------------------------------------------
# roofline execution time
# ---------------------------------------------------------------------------
def roofline_time(hw: Hardware, flops: float, bytes_: float) -> float:
    if flops == 0 and bytes_ == 0:
        return 0.0
    return max(flops / (hw.peak_flops * hw.mfu),
               bytes_ / (hw.hbm_bw * hw.mbu)) + hw.kernel_overhead


@dataclass
class BatchWork:
    """Composition of one batch iteration (the unit Algorithm 1 builds)."""
    decode_batch: int = 0
    decode_context: int = 0          # average context length of decodes
    prefill_tokens: int = 0          # chunked-prefill tokens this iteration
    prefill_context: int = 0         # avg context (incl. already-done chunks)
    prefill_batch: int = 0
    encode_images: int = 0


def batch_time(cfg: ModelConfig, hw: Hardware, work: BatchWork, *,
               parallel_streams: bool = True, tp: int = 1) -> float:
    """Execution time of one mixed batch on one instance (tp-way sharded).

    Language work (prefill+decode) is operator-fused into one pass (paper:
    flattened tokens + offset metadata); encode runs in the second stream.
    """
    lf = lb = 0.0
    if work.decode_batch:
        f, b = stage_cost(cfg, "decode", batch=work.decode_batch,
                          context=max(1, work.decode_context))
        lf += f
        lb += b
    if work.prefill_tokens:
        f, b = stage_cost(cfg, "prefill", n_tokens=work.prefill_tokens,
                          batch=max(1, work.prefill_batch),
                          context=max(1, work.prefill_context))
        lf += f
        lb += b
    ef = eb = 0.0
    if work.encode_images:
        ef, eb = stage_cost(cfg, "encode", n_images=work.encode_images)
    ef, eb, lf, lb = ef / tp, eb / tp, lf / tp, lb / tp
    if not (ef or lf):
        return 0.0
    lang_mfu = hw.prefill_mfu
    if parallel_streams:
        t = max(ef / (hw.peak_flops * hw.encode_mfu)
                + lf / (hw.peak_flops * lang_mfu),
                (eb + lb) / (hw.hbm_bw * hw.serve_mbu))
        return t + hw.iter_overhead
    t = 0.0
    if lf:
        t += max(lf / (hw.peak_flops * lang_mfu),
                 lb / (hw.hbm_bw * hw.serve_mbu))
    if ef:
        t += max(ef / (hw.peak_flops * hw.encode_mfu),
                 eb / (hw.hbm_bw * hw.serve_mbu))
    return t + hw.iter_overhead


def migration_time(hw: Hardware, bytes_: float, rtt: float = 0.5e-3) -> float:
    """Pull-based cache migration: control RTT + asynchronous bulk transfer."""
    return rtt + bytes_ / hw.link_bw


@dataclass(frozen=True)
class CacheFeedback:
    """Measured prefix/encode cache effectiveness, fed back into the
    autotuner's workload model (DESIGN.md §14).

    A prefix hit removes prefill *compute* for the matched tokens and an
    encode hit removes the whole encode pass — but neither shrinks the
    decode-time attention context: adopted pages are still read every
    decode step.  So only ``prefill_tokens`` and ``images`` are
    discounted; ``decode_context`` must stay at the full value.

    Build one from ``HydraServer.cache_stats()`` /
    ``Engine.cache_stats()``:

        fb = CacheFeedback.from_stats(engine.cache_stats())
        autotune_disaggregation(cfg, hw, profile, slo, cache=fb)
    """
    prefix_hit_rate: float = 0.0     # fraction of prompt tokens adopted
    encode_hit_rate: float = 0.0     # fraction of images skipping encode

    def effective_prefill(self, tokens: float) -> float:
        return tokens * (1.0 - min(max(self.prefix_hit_rate, 0.0), 1.0))

    def effective_images(self, images: float) -> float:
        return images * (1.0 - min(max(self.encode_hit_rate, 0.0), 1.0))

    @classmethod
    def from_stats(cls, stats: dict) -> "CacheFeedback":
        return cls(prefix_hit_rate=float(stats.get("prefix_hit_rate", 0.0)),
                   encode_hit_rate=float(stats.get("encode_hit_rate", 0.0)))
