"""Hybrid EPD Disaggregation (paper §4.4, DESIGN.md §7): enumerate
disaggregation methods and instance ratios, simulate each under the
workload + SLO profile, and select the configuration maximizing goodput.

``search_disaggregation`` is the exhaustive reference: every candidate gets
a full serial goodput bisection.  ``core.autotuner`` finds the same argmax
with cost-model pruning, warm starts, caching, and parallel fan-out — use
it for anything bigger than a toy grid (DESIGN.md §7.1)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.costmodel import Hardware
from repro.core.metrics import goodput, slo_attainment, summarize
from repro.core.request import SLO
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import WorkloadProfile, make_requests


def enumerate_disaggs(n_gpus: int = 8, *, multimodal: bool = True,
                      methods: Optional[list] = None) -> list[DisaggConfig]:
    out = []
    methods = methods or (["EPD", "EP+D", "ED+P", "E+P+D"] if multimodal
                          else ["PD", "P+D"])
    if "EPD" in methods:
        out.append(DisaggConfig({"EPD": n_gpus}))
    if "PD" in methods:
        out.append(DisaggConfig({"PD": n_gpus}))
    if "EP+D" in methods:
        out += [DisaggConfig({"EP": k, "D": n_gpus - k})
                for k in range(1, n_gpus)]
    if "ED+P" in methods:
        out += [DisaggConfig({"ED": k, "P": n_gpus - k})
                for k in range(1, n_gpus)]
    if "P+D" in methods:
        out += [DisaggConfig({"P": k, "D": n_gpus - k})
                for k in range(1, n_gpus)]
    if "E+P+D" in methods:
        for e in range(1, n_gpus - 1):
            for p in range(1, n_gpus - e):
                d = n_gpus - e - p
                if d >= 1:
                    out.append(DisaggConfig({"E": e, "P": p, "D": d}))
    return out


def simulate_once(cfg: ModelConfig, hw: Hardware, disagg: DisaggConfig,
                  profile: WorkloadProfile, slo: SLO, *, rate: float,
                  n_requests: int = 150, policy: str = "hydra",
                  image_tokens: Optional[int] = None, seed: int = 0,
                  tp: int = 1):
    image_tokens = image_tokens if image_tokens is not None else cfg.media_tokens
    reqs = make_requests(profile, rate=rate, n=n_requests,
                         image_tokens_per_image=image_tokens, slo=slo,
                         seed=seed)
    cluster = Cluster(cfg, hw, disagg, slo, policy_name=policy, tp=tp)
    sim = Simulator(cluster)
    horizon = reqs[-1].arrival + 120.0
    done = sim.run(reqs, until=horizon)
    return summarize(done, rate, reqs[-1].arrival), done, cluster


@dataclass
class SearchResult:
    disagg: DisaggConfig
    goodput: float
    details: list  # (DisaggConfig, goodput) for every candidate
    n_sims: int = 0  # simulator invocations spent by the search


def search_disaggregation(cfg: ModelConfig, hw: Hardware,
                          profile: WorkloadProfile, slo: SLO, *,
                          n_gpus: int = 8, policy: str = "hydra",
                          n_requests: int = 120,
                          candidates: Optional[list] = None,
                          image_tokens: Optional[int] = None,
                          max_rate: float = 64.0, seed: int = 0) -> SearchResult:
    """Exhaustive profile-driven search (one full bisection per candidate)."""
    multimodal = profile.p_image > 0
    cands = candidates or enumerate_disaggs(n_gpus, multimodal=multimodal)
    scored = []
    n_sims = 0
    for dc in cands:
        def attain(rate, _dc=dc):
            nonlocal n_sims
            n_sims += 1
            stats, _, _ = simulate_once(cfg, hw, _dc, profile, slo, rate=rate,
                                        n_requests=n_requests, policy=policy,
                                        image_tokens=image_tokens, seed=seed)
            return stats.attainment
        g = goodput(attain, hi=max_rate, grow_to=max_rate)
        scored.append((dc, g))
    best = max(scored, key=lambda x: x[1])
    return SearchResult(disagg=best[0], goodput=best[1], details=scored,
                        n_sims=n_sims)
