"""Serving metrics: TTFT / TPOT / SLO attainment / goodput (paper §2.3,
DESIGN.md §8).  ``goodput`` here is the exhaustive bisection; the autotuner
(DESIGN.md §7.1) wraps a warm-started, cached variant of the same search."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable


def slo_attainment(requests) -> float:
    done = [r for r in requests if r.first_token_time is not None]
    if not done:
        return 0.0
    return sum(1 for r in done if r.meets_slo()) / len(done)


def quantile(xs, q):
    if not xs:
        return float("nan")
    xs = sorted(xs)
    i = min(len(xs) - 1, max(0, int(math.ceil(q * len(xs))) - 1))
    return xs[i]


@dataclass
class RunStats:
    rate: float
    attainment: float
    p50_ttft: float
    p90_ttft: float
    p50_tpot: float
    p90_tpot: float
    throughput_rps: float
    tokens_per_s: float


def summarize(requests, rate: float, horizon: float) -> RunStats:
    fin = [r for r in requests if r.finish_time is not None]
    ttfts = [r.ttft() for r in fin if r.ttft() is not None]
    tpots = [t for r in fin for t in r.tpots()]
    toks = sum(r.tokens_out for r in fin)
    return RunStats(
        rate=rate,
        attainment=slo_attainment(fin),
        p50_ttft=quantile(ttfts, 0.5),
        p90_ttft=quantile(ttfts, 0.9),
        p50_tpot=quantile(tpots, 0.5),
        p90_tpot=quantile(tpots, 0.9),
        throughput_rps=len(fin) / horizon if horizon else 0.0,
        tokens_per_s=toks / horizon if horizon else 0.0,
    )


def goodput(run_at_rate: Callable[[float], float], *, lo: float = 0.25,
            hi: float = 64.0, target: float = 0.9, tol: float = 0.125,
            max_iters: int = 12, grow_to: float = 512.0) -> float:
    """Max request rate with SLO attainment >= target (bisection sweep).

    ``run_at_rate(rate) -> attainment``.  The bracket grows past ``hi`` on
    success, up to ``grow_to``; pass ``grow_to=hi`` to make ``hi`` a hard
    cap (the disaggregation searches do, so exhaustive and autotuned runs
    explore the same rate range).
    """
    if run_at_rate(lo) < target:
        return 0.0
    # grow hi until failure (or the cap, which then needs no bisection)
    while run_at_rate(hi) >= target:
        if hi >= grow_to:
            return hi
        lo = hi
        hi = min(hi * 2, grow_to)
    for _ in range(max_iters):
        if hi - lo <= tol:
            break
        mid = 0.5 * (lo + hi)
        if run_at_rate(mid) >= target:
            lo = mid
        else:
            hi = mid
    return lo
