"""Request / stage lifecycle model (paper §4.1 Request Processor,
DESIGN.md §1.2; SLO accounting: DESIGN.md §8).

A request is decomposed into a sequence of stage *tasks* — encode, prefill,
decode (+ migrate between instances) — ahead of time, with control
parameters (token counts, cache footprints) precomputed so schedulers only
do queue work on the hot path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Optional


class Stage(str, Enum):
    ENCODE = "encode"
    PREFILL = "prefill"
    DECODE = "decode"
    MIGRATE = "migrate"
    DONE = "done"


@dataclass(frozen=True)
class SLO:
    ttft: float   # seconds
    tpot: float   # seconds


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls (DESIGN.md §13).

    ``temperature <= 0`` selects greedy decoding (bit-exact argmax — the
    pre-streaming engine behavior).  ``top_k <= 0`` / ``top_p >= 1``
    disable the respective filters.  ``stop`` holds token ids: sampling
    one of them ends the request with ``finish_reason="stop"`` and the
    stop token is not included in the output.  ``seed=None`` derives a
    per-request seed from the rid at submit, so replays are deterministic
    regardless of how requests are batched together.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop: tuple = ()
    max_tokens: int = 16


@dataclass(frozen=True)
class StreamEvent:
    """One element of a request's output stream (engine API, DESIGN.md §13).

    kind: "first_token" | "token" | "finish".  Token events carry the
    sampled token id; the finish event carries the reason
    ("length" | "stop" | "abort" | "error" — "error" means the request was
    shed by the fault-tolerance layer, DESIGN.md §15).
    """
    rid: int
    kind: str
    t: float
    token: Optional[int] = None
    finish_reason: Optional[str] = None


@dataclass
class Request:
    rid: int
    arrival: float
    n_images: int
    image_tokens: int            # total media tokens (all images)
    prompt_tokens: int
    max_new_tokens: int
    slo: SLO
    # vision media joins the LM sequence (LLaVA-style); audio frames feed
    # cross-attention instead and never enter the prefill stream
    media_in_lm: bool = True
    # sampling controls; None means greedy (simulator requests never sample)
    sampling: Optional[SamplingParams] = None

    # --- lifecycle state ---
    stage: Stage = Stage.ENCODE
    prefill_done: int = 0        # prompt+image tokens already prefilled
    tokens_out: int = 0
    ready_at: float = 0.0        # not schedulable before this (migration pull)

    # --- cache-hit metadata (DESIGN.md §14) ---
    # tokens adopted from the shared prefix index: counted into
    # prefill_done at admission, so schedulers/reservations only see the
    # miss suffix; kept separately for hit-rate accounting
    prefix_cached_tokens: int = 0
    # encode stage skipped via the image-embedding cache (the cached
    # embeddings install lazily at the first prefill batch)
    encode_cached: bool = False

    # --- failure recovery (DESIGN.md §15) ---
    # output tokens already emitted before a failure forced a replay: the
    # re-prefill context ends at the last emitted token, so completing it
    # fast-forwards ``tokens_out`` here instead of re-emitting a first token
    replayed_tokens: int = 0
    n_recoveries: int = 0        # replays survived (bounded by the server)

    # --- measurements ---
    first_token_time: Optional[float] = None
    token_times: list = field(default_factory=list)
    stage_log: list = field(default_factory=list)  # (stage, t_start, t_end)
    finish_time: Optional[float] = None
    finish_reason: Optional[str] = None  # "length"|"stop"|"abort"|"error"

    def __post_init__(self):
        self.stage = Stage.ENCODE if self.n_images > 0 else Stage.PREFILL
        self.ready_at = self.arrival

    # ------------------------------------------------------------------
    @property
    def prefill_total(self) -> int:
        """LM prefill length: vision tokens enter the LM alongside text."""
        return (self.image_tokens if self.media_in_lm else 0) + self.prompt_tokens

    @property
    def context_len(self) -> int:
        return self.prefill_total + self.tokens_out

    @property
    def prefill_remaining(self) -> int:
        return self.prefill_total - self.prefill_done

    @property
    def done(self) -> bool:
        return self.stage == Stage.DONE

    # ------------------------------------------------------------------
    def advance_after_encode(self):
        self.stage = Stage.PREFILL

    def advance_after_prefill_chunk(self, chunk: int, now: float):
        self.prefill_done += chunk
        if self.prefill_done >= self.prefill_total:
            if self.replayed_tokens > 0:
                # recovery replay (DESIGN.md §15): the first
                # ``replayed_tokens`` outputs were already emitted before
                # the failure and the re-prefilled context ends at the last
                # of them — fast-forward the counter and resume decode; no
                # re-emission, no first-token restamp (TTFT is history)
                self.tokens_out = self.replayed_tokens
                self.replayed_tokens = 0
                if self.tokens_out < self.max_new_tokens:
                    self.stage = Stage.DECODE
                else:
                    self.finish("length", now)
                return
            # prefill produces the first token
            self.tokens_out = 1
            self.first_token_time = now
            self.token_times.append(now)
            if self.tokens_out < self.max_new_tokens:
                self.stage = Stage.DECODE
            else:
                self.finish("length", now)

    def advance_after_decode_step(self, now: float):
        self.tokens_out += 1
        self.token_times.append(now)
        if self.tokens_out >= self.max_new_tokens:
            self.finish("length", now)

    def finish(self, reason: str, now: float):
        self.stage = Stage.DONE
        self.finish_reason = reason
        self.finish_time = now

    # ------------------------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def tpots(self) -> list:
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    def meets_slo(self) -> bool:
        """Paper §2.3: TTFT <= SLO and 90% of TPOT values <= TPOT SLO."""
        t = self.ttft()
        if t is None or t > self.slo.ttft:
            return False
        tp = self.tpots()
        if not tp:
            return True
        within = sum(1 for x in tp if x <= self.slo.tpot)
        return within >= 0.9 * len(tp)
