"""Discrete-event cluster simulator.

Runs the *same* scheduling code (Algorithm 1 / baseline policies) as the
real engine, with batch execution times supplied by the analytical cost
model (paper Table 2 + roofline) for a chosen hardware profile.  This is
how the paper-scale experiments (8xH800, 7B MLLMs, Poisson arrivals) run
inside a CPU-only container — see DESIGN.md §3.

Migration is pull-based (paper §4.3, DESIGN.md §4): the target instance
admits a request only when it has cache space, then pulls the KV/image
cache; the request becomes schedulable at ``now + migration_time``.

Clusters may be heterogeneous (DESIGN.md §7.2): each role group of a
``DisaggConfig`` can carry its own ``Hardware`` profile and TP degree via
``RoleSpec``, and budgets/cost-model times resolve per instance.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core.batch_scheduler import POLICIES, Batch, Policy
from repro.core.budgets import Budgets, compute_budgets
from repro.core.costmodel import BatchWork, Hardware
from repro.core.request import Request, Stage

ROLE_SETS = {
    "E": frozenset({Stage.ENCODE}),
    "P": frozenset({Stage.PREFILL}),
    "D": frozenset({Stage.DECODE}),
    "EP": frozenset({Stage.ENCODE, Stage.PREFILL}),
    "ED": frozenset({Stage.ENCODE, Stage.DECODE}),
    "PD": frozenset({Stage.PREFILL, Stage.DECODE}),
    "EPD": frozenset({Stage.ENCODE, Stage.PREFILL, Stage.DECODE}),
}


class Instance:
    def __init__(self, iid: int, role_name: str, cfg: ModelConfig,
                 hw: Hardware, budgets: Budgets, policy: Policy, *,
                 tp: int = 1, kv_capacity_tokens: Optional[int] = None,
                 image_capacity_tokens: Optional[int] = None):
        self.iid = iid
        self.role_name = role_name
        self.role = ROLE_SETS[role_name]
        self.cfg = cfg
        self.hw = hw
        self.budgets = budgets
        self.policy = policy
        self.tp = tp
        self.running: list[Request] = []
        self.waiting: deque = deque()   # (Request, pull_bytes)
        self.busy = False
        self.total_busy_time = 0.0
        self.iters = 0

        if kv_capacity_tokens is None:
            weight_bytes = cm.active_param_count(cfg) * cm.BYTES  # rough
            per_tok = max(cm.kv_bytes_per_token(cfg), 1)
            free = max(hw.mem_bytes * tp * 0.9 - weight_bytes, per_tok * 4096)
            kv_capacity_tokens = int(free / per_tok)
        self.kv_capacity_tokens = kv_capacity_tokens
        if image_capacity_tokens is None:
            image_capacity_tokens = int(hw.mem_bytes * 0.2 /
                                        max(cfg.d_model * cm.BYTES, 1))
        self.image_capacity_tokens = image_capacity_tokens

    # ------------------------------------------------------------------
    def kv_used(self) -> int:
        return sum(r.context_len for r in self.running
                   if r.stage in (Stage.PREFILL, Stage.DECODE))

    def img_used(self) -> int:
        return sum(r.image_tokens for r in self.running)

    def has_capacity(self, r: Request) -> bool:
        if r.stage in (Stage.PREFILL, Stage.DECODE):
            need = r.prefill_total + r.max_new_tokens
            if self.kv_used() + need > self.kv_capacity_tokens:
                return False
        if r.stage == Stage.ENCODE:
            if self.img_used() + r.image_tokens > self.image_capacity_tokens:
                return False
        return True

    def enqueue(self, r: Request, pull_bytes: float = 0.0):
        self.waiting.append((r, pull_bytes))

    def pop_waiting(self, stage: Optional[Stage], now: float):
        """Admit the next waiting request (FCFS within stage filter).

        Pull-based migration: admission starts the cache pull; the request
        joins ``running`` but is not schedulable until ``ready_at``.
        Returns the request if it is immediately schedulable, else None-loops
        by design (callers skip non-ready ones).
        """
        for i, (r, pull_bytes) in enumerate(self.waiting):
            if stage is not None and r.stage != stage:
                continue
            if not self.has_capacity(r):
                continue
            del self.waiting[i]
            if pull_bytes > 0:
                t_mig = cm.migration_time(self.hw, pull_bytes)
                r.ready_at = now + t_mig
                r.stage_log.append(("migrate", now, now + t_mig))
            self.running.append(r)
            return r
        return None

    def remove(self, r: Request):
        if r in self.running:
            self.running.remove(r)


@dataclass(frozen=True)
class RoleSpec:
    """One role group of a disaggregation: instance count plus optional
    per-role hardware/TP overrides (heterogeneous clusters, DESIGN.md §7.2).

    ``hw=None`` / ``tp=None`` inherit the cluster-wide defaults, so a plain
    ``DisaggConfig({"EP": 2, "D": 6})`` behaves exactly as before.
    """
    count: int
    hw: Optional[Hardware] = None
    tp: Optional[int] = None


@dataclass
class DisaggConfig:
    """A disaggregation method: mapping role -> instance count or RoleSpec.

    Values may be plain ints (homogeneous: every instance uses the cluster
    default ``Hardware``/TP) or :class:`RoleSpec` (heterogeneous: e.g.
    encode on memory-light chips, decode on bandwidth-heavy ones).
    """
    counts: dict

    def spec(self, role: str) -> RoleSpec:
        v = self.counts[role]
        return v if isinstance(v, RoleSpec) else RoleSpec(count=v)

    @property
    def roles(self) -> list:
        """[(role_name, RoleSpec)] for every non-empty role group."""
        return [(r, self.spec(r)) for r in self.counts if self.spec(r).count]

    @property
    def heterogeneous(self) -> bool:
        return any(s.hw is not None or s.tp is not None
                   for _, s in self.roles)

    @property
    def total_instances(self) -> int:
        return sum(s.count for _, s in self.roles)

    @property
    def name(self) -> str:
        parts = []
        for role, s in self.roles:
            p = f"{s.count}{role}"
            if s.hw is not None:
                p += f"@{s.hw.name}"
            if s.tp is not None and s.tp != 1:
                p += f"tp{s.tp}"
            parts.append(p)
        return "+".join(parts)

    @property
    def method(self) -> str:
        roles = sorted(r for r, _ in self.roles)
        return "+".join(roles)


class Cluster:
    def __init__(self, cfg: ModelConfig, hw: Hardware, disagg: DisaggConfig,
                 slo, *, policy_name: str = "hydra", tp: int = 1,
                 ref_decode_batch: int = 64):
        self.cfg = cfg
        self.hw = hw          # default hardware for roles without an override
        self.disagg = disagg
        self.policy = POLICIES[policy_name]
        # budgets resolve per (hardware, tp) — heterogeneous role groups get
        # their own Algorithm-1 token/image budgets, not the cluster's
        budget_cache: dict = {}
        self.instances: list[Instance] = []
        iid = itertools.count()
        for role, s in disagg.roles:
            inst_hw = s.hw if s.hw is not None else hw
            inst_tp = s.tp if s.tp is not None else tp
            key = (inst_hw.name, inst_tp)
            if key not in budget_cache:
                budget_cache[key] = compute_budgets(
                    cfg, inst_hw, slo.tpot, tp=inst_tp,
                    ref_decode_batch=ref_decode_batch)
            for _ in range(s.count):
                self.instances.append(Instance(next(iid), role, cfg, inst_hw,
                                               budget_cache[key], self.policy,
                                               tp=inst_tp))
        self._rr = {s: 0 for s in Stage}

    def by_stage(self, stage: Stage) -> list:
        return [i for i in self.instances if stage in i.role]

    @staticmethod
    def _speed(inst: Instance, stage: Stage) -> float:
        """Relative service speed of an instance for a stage: decode is
        bandwidth-bound, encode/prefill compute-bound (paper §3.1)."""
        if stage == Stage.DECODE:
            return inst.hw.hbm_bw * inst.tp
        return inst.hw.peak_flops * inst.tp

    def route(self, r: Request, stage: Stage) -> Instance:
        """Load-balance: least outstanding work, normalized by instance
        speed so heterogeneous instances fill proportionally to capacity."""
        cands = self.by_stage(stage)
        if not cands:
            raise RuntimeError(f"no instance serves stage {stage}")
        return min(cands, key=lambda i: ((len(i.running) + len(i.waiting) + 1)
                                         / self._speed(i, stage)))

    def dispatch_new(self, r: Request):
        inst = self.route(r, r.stage)
        inst.enqueue(r, pull_bytes=0.0)
        return inst

    def migrate(self, r: Request, src: Instance):
        """Request finished a stage the source can't continue — move it."""
        src.remove(r)
        target = self.route(r, r.stage)
        if r.stage == Stage.PREFILL:      # E -> P: image cache moves
            pull = cm.image_cache_bytes(self.cfg, 1) * max(r.n_images, 1)
        else:                             # P -> D: KV cache moves
            pull = r.context_len * cm.kv_bytes_per_token(self.cfg)
            if pull == 0:                 # SSM: fixed-size state
                pull = cm.ssm_state_bytes(self.cfg)
        target.enqueue(r, pull_bytes=pull)
        return target


# ---------------------------------------------------------------------------
# event loop
# ---------------------------------------------------------------------------
class Simulator:
    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self.events: list = []   # (time, seq, kind, payload)
        self._seq = itertools.count()
        self.completed: list[Request] = []

    def push(self, t, kind, payload):
        heapq.heappush(self.events, (t, next(self._seq), kind, payload))

    # ------------------------------------------------------------------
    def _batch_work(self, batch: Batch) -> BatchWork:
        w = BatchWork()
        if batch.decode:
            w.decode_batch = len(batch.decode)
            w.decode_context = int(sum(r.context_len for r in batch.decode)
                                   / len(batch.decode))
        if batch.prefill:
            w.prefill_tokens = sum(c for _, c in batch.prefill)
            w.prefill_batch = len(batch.prefill)
            w.prefill_context = int(sum(r.prefill_done + c / 2
                                        for r, c in batch.prefill)
                                    / len(batch.prefill))
        if batch.encode:
            w.encode_images = sum(n for _, n in batch.encode)
        return w

    def _start_iteration(self, inst: Instance, now: float):
        if inst.busy:
            return
        batch = inst.policy.build(inst, now)
        if batch.empty:
            return
        dt = cm.batch_time(inst.cfg, inst.hw, self._batch_work(batch),
                           parallel_streams=inst.policy.parallel_streams,
                           tp=inst.tp)
        inst.busy = True
        inst.total_busy_time += dt
        inst.iters += 1
        self.push(now + dt, "iter_done", (inst, batch, now))

    def _finish_iteration(self, inst: Instance, batch: Batch, t0: float,
                          now: float):
        inst.busy = False
        cfg = self.cluster.cfg
        for r, n in batch.encode:
            r.stage_log.append(("encode_exec", t0, now))
            if r.stage == Stage.ENCODE:
                r.advance_after_encode()
                if Stage.PREFILL not in inst.role:
                    self.cluster.migrate(r, inst)
        for r, chunk in batch.prefill:
            r.stage_log.append(("prefill_exec", t0, now))
            r.advance_after_prefill_chunk(chunk, now)
            if r.stage == Stage.DECODE and Stage.DECODE not in inst.role:
                self.cluster.migrate(r, inst)
            elif r.stage == Stage.DONE:
                inst.remove(r)
                r.finish_time = now
                self.completed.append(r)
        for r in batch.decode:
            r.stage_log.append(("decode_exec", t0, now))
            r.advance_after_decode_step(now)
            if r.stage == Stage.DONE:
                inst.remove(r)
                self.completed.append(r)
        self._wake_all(now)

    def _wake_all(self, now: float):
        for inst in self.cluster.instances:
            if not inst.busy:
                self._start_iteration(inst, now)
            if not inst.busy:
                # nothing schedulable now; wake at the next ready_at
                nxt = [r.ready_at for r in inst.running if r.ready_at > now]
                if nxt:
                    self.push(min(nxt), "wake", inst)

    # ------------------------------------------------------------------
    def run(self, requests: list, *, until: Optional[float] = None):
        for r in requests:
            self.push(r.arrival, "arrival", r)
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            if until is not None and t > until:
                break
            if kind == "arrival":
                self.cluster.dispatch_new(payload)
                self._wake_all(t)
            elif kind == "iter_done":
                inst, batch, t0 = payload
                self._finish_iteration(inst, batch, t0, t)
            elif kind == "wake":
                self._wake_all(t)
        return self.completed
