"""Multimodal serving workloads: dataset profiles + Poisson arrivals.

The five paper datasets (TextCaps, POPE, MME, TextVQA, VizWiz) are modeled
by their per-request token statistics (approximating paper Fig 9 — the
datasets themselves carry no timestamps, so the paper likewise samples
request bodies and synthesizes Poisson arrivals).  Image-token counts per
image depend on the model (LLaVA-1.5: 576; LLaVA-NeXT: ~2880 tiles;
Qwen2-VL: resolution-adaptive ~1200).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.request import Request, SLO


@dataclass(frozen=True)
class WorkloadProfile:
    name: str
    p_image: float            # fraction of requests carrying images
    n_images: int
    prompt_mean: float        # lognormal parameters for text prompt length
    prompt_sigma: float
    output_mean: float
    output_sigma: float

    def sample_lengths(self, rng: np.random.Generator):
        prompt = int(np.clip(rng.lognormal(np.log(self.prompt_mean),
                                           self.prompt_sigma), 4, 2048))
        out = int(np.clip(rng.lognormal(np.log(self.output_mean),
                                        self.output_sigma), 1, 1024))
        n_img = self.n_images if rng.random() < self.p_image else 0
        return n_img, prompt, out


# Approximations of paper Fig 9 (LLaVA-NeXT workload shown there):
# captioning produces long outputs, classification (MME/POPE) near-binary
# outputs, VQA short answers.
PROFILES = {
    "textcaps": WorkloadProfile("textcaps", 1.0, 1, 44, 0.25, 90, 0.45),
    "pope":     WorkloadProfile("pope", 1.0, 1, 35, 0.20, 4, 0.40),
    "mme":      WorkloadProfile("mme", 1.0, 1, 45, 0.25, 4, 0.40),
    "textvqa":  WorkloadProfile("textvqa", 1.0, 1, 50, 0.30, 14, 0.50),
    "vizwiz":   WorkloadProfile("vizwiz", 1.0, 1, 40, 0.30, 48, 0.60),
    # text-only profile for the language-only assigned archs
    "text":     WorkloadProfile("text", 0.0, 0, 256, 0.60, 128, 0.60),
}

# image tokens per image, per evaluation model (paper §5.1 Models)
IMAGE_TOKENS = {
    "llava-1.5-7b": 576,
    "llava-next-7b": 2880,
    "qwen2-vl-7b": 1236,
}

# paper Table 3 SLO settings (seconds): (model, dataset) -> SLO
PAPER_SLOS = {
    ("llava-1.5-7b", "vizwiz"): SLO(8.0, 0.04),
    ("llava-1.5-7b", "textvqa"): SLO(0.25, 0.04),
    ("llava-1.5-7b", "mme"): SLO(0.25, 0.06),
    ("llava-1.5-7b", "pope"): SLO(0.25, 0.04),
    ("llava-1.5-7b", "textcaps"): SLO(0.25, 0.04),
    ("llava-next-7b", "vizwiz"): SLO(8.0, 0.12),
    ("llava-next-7b", "textvqa"): SLO(8.0, 0.12),
    ("llava-next-7b", "mme"): SLO(8.0, 0.14),
    ("llava-next-7b", "pope"): SLO(8.0, 0.06),
    ("llava-next-7b", "textcaps"): SLO(8.0, 0.08),
    ("qwen2-vl-7b", "vizwiz"): SLO(8.0, 0.14),
    ("qwen2-vl-7b", "textvqa"): SLO(1.0, 0.12),
    ("qwen2-vl-7b", "mme"): SLO(1.0, 0.14),
    ("qwen2-vl-7b", "pope"): SLO(1.0, 0.04),
    ("qwen2-vl-7b", "textcaps"): SLO(1.0, 0.14),
    ("text", "text"): SLO(1.0, 0.05),
}


def slo_for(model: str, dataset: str) -> SLO:
    return PAPER_SLOS.get((model, dataset), SLO(1.0, 0.08))


def make_requests(profile: WorkloadProfile, *, rate: float, n: int,
                  image_tokens_per_image: int, slo: SLO,
                  seed: int = 0) -> list[Request]:
    """Poisson arrival process at ``rate`` req/s; fixed output lengths
    (paper methodology: max_tokens + ignore_eos for engine-fair loads)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n):
        t += rng.exponential(1.0 / rate)
        n_img, prompt, gen = profile.sample_lengths(rng)
        out.append(Request(
            rid=rid, arrival=t, n_images=n_img,
            image_tokens=n_img * image_tokens_per_image,
            prompt_tokens=prompt, max_new_tokens=gen, slo=slo))
    return out


# ---------------------------------------------------------------------------
# cache-sensitive traces (ISSUE 6): multi-turn conversations and repeated
# ("hot") images — the request mixes where prefix / encode caching decides
# TTFT (EPD-Serve's multi-turn evaluation; TCM-Serve's repeated-visual-
# content observation, see PAPERS.md)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TraceItem:
    """One request of a cache-sensitive trace.

    ``conv``/``turn`` identify the conversation a request belongs to: each
    turn resends the full prior history (system prompt + earlier turns +
    earlier answers) plus ``new_tokens`` fresh tokens, so a prefix cache
    can skip everything but the fresh suffix.  ``image_id`` keys a shared
    image pool: two items with the same id carry byte-identical media, so
    an embedding cache can skip the encode stage for repeats.
    """
    arrival: float
    conv: int                 # conversation id (-1: independent request)
    turn: int                 # 0-based turn index within the conversation
    new_tokens: int           # fresh prompt tokens this turn
    out_tokens: int           # output budget this turn
    image_id: int = -1        # shared-image pool id (-1: no image)


def multiturn_trace(*, n_convs: int, turns: int, rate: float,
                    system_tokens: int = 32, turn_tokens: int = 24,
                    out_tokens: int = 8, p_image: float = 0.0,
                    image_pool: int = 4, zipf_a: float = 1.5,
                    seed: int = 0) -> list[TraceItem]:
    """Interleaved multi-turn conversations under Poisson arrivals.

    Turn 0 carries the system prompt + first user message; turn t > 0
    resends the whole history and appends ~``turn_tokens`` fresh tokens.
    A turn only arrives after the previous one (arrival ordering respects
    causality within a conversation).  Images, when present, stay fixed
    across a conversation's turns (the common VQA-chat shape) and draw
    from a Zipf-distributed shared pool so some images are hot.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    items = []
    img_ids = [-1] * n_convs
    for c in range(n_convs):
        if rng.random() < p_image:
            img_ids[c] = int(min(rng.zipf(zipf_a), image_pool) - 1)
    last_t = [0.0] * n_convs
    for turn in range(turns):
        for c in range(n_convs):
            t += rng.exponential(1.0 / rate)
            arr = max(t, last_t[c])
            last_t[c] = arr
            fresh = system_tokens + turn_tokens if turn == 0 else \
                max(4, int(rng.normal(turn_tokens, turn_tokens / 4)))
            items.append(TraceItem(arrival=arr, conv=c, turn=turn,
                                   new_tokens=fresh, out_tokens=out_tokens,
                                   image_id=img_ids[c]))
    items.sort(key=lambda it: it.arrival)
    return items


def repeated_image_trace(*, n: int, rate: float, image_pool: int = 4,
                         zipf_a: float = 1.5, prompt_tokens: int = 32,
                         out_tokens: int = 8,
                         seed: int = 0) -> list[TraceItem]:
    """Independent single-turn VQA requests whose images draw from a small
    Zipf-distributed pool: a handful of hot images receive most of the
    traffic, so encode results and their media pages are highly reusable."""
    rng = np.random.default_rng(seed)
    t = 0.0
    items = []
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        img = int(min(rng.zipf(zipf_a), image_pool) - 1)
        fresh = max(4, int(rng.normal(prompt_tokens, prompt_tokens / 4)))
        items.append(TraceItem(arrival=t, conv=-1, turn=0, new_tokens=fresh,
                               out_tokens=out_tokens, image_id=img))
    return items


def trace_requests(items: list[TraceItem], *,
                   image_tokens_per_image: int, slo: SLO) -> list[Request]:
    """Lower a TraceItem list to simulator ``Request``s: turn t's prompt
    length is the conversation's cumulative history (prior prompts + prior
    outputs) plus its fresh tokens.  Real-engine drivers instead build the
    actual token bodies turn by turn (benchmarks/bench_cache.py)."""
    hist: dict[int, int] = {}
    out = []
    for rid, it in enumerate(items):
        prior = hist.get(it.conv, 0) if it.conv >= 0 else 0
        prompt = prior + it.new_tokens
        if it.conv >= 0:
            hist[it.conv] = prompt + it.out_tokens
        n_img = 1 if it.image_id >= 0 else 0
        out.append(Request(
            rid=rid, arrival=it.arrival, n_images=n_img,
            image_tokens=n_img * image_tokens_per_image,
            prompt_tokens=prompt, max_new_tokens=it.out_tokens, slo=slo))
    return out
