"""Multimodal serving workloads: dataset profiles + Poisson arrivals.

The five paper datasets (TextCaps, POPE, MME, TextVQA, VizWiz) are modeled
by their per-request token statistics (approximating paper Fig 9 — the
datasets themselves carry no timestamps, so the paper likewise samples
request bodies and synthesizes Poisson arrivals).  Image-token counts per
image depend on the model (LLaVA-1.5: 576; LLaVA-NeXT: ~2880 tiles;
Qwen2-VL: resolution-adaptive ~1200).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.request import Request, SLO


@dataclass(frozen=True)
class WorkloadProfile:
    name: str
    p_image: float            # fraction of requests carrying images
    n_images: int
    prompt_mean: float        # lognormal parameters for text prompt length
    prompt_sigma: float
    output_mean: float
    output_sigma: float

    def sample_lengths(self, rng: np.random.Generator):
        prompt = int(np.clip(rng.lognormal(np.log(self.prompt_mean),
                                           self.prompt_sigma), 4, 2048))
        out = int(np.clip(rng.lognormal(np.log(self.output_mean),
                                        self.output_sigma), 1, 1024))
        n_img = self.n_images if rng.random() < self.p_image else 0
        return n_img, prompt, out


# Approximations of paper Fig 9 (LLaVA-NeXT workload shown there):
# captioning produces long outputs, classification (MME/POPE) near-binary
# outputs, VQA short answers.
PROFILES = {
    "textcaps": WorkloadProfile("textcaps", 1.0, 1, 44, 0.25, 90, 0.45),
    "pope":     WorkloadProfile("pope", 1.0, 1, 35, 0.20, 4, 0.40),
    "mme":      WorkloadProfile("mme", 1.0, 1, 45, 0.25, 4, 0.40),
    "textvqa":  WorkloadProfile("textvqa", 1.0, 1, 50, 0.30, 14, 0.50),
    "vizwiz":   WorkloadProfile("vizwiz", 1.0, 1, 40, 0.30, 48, 0.60),
    # text-only profile for the language-only assigned archs
    "text":     WorkloadProfile("text", 0.0, 0, 256, 0.60, 128, 0.60),
}

# image tokens per image, per evaluation model (paper §5.1 Models)
IMAGE_TOKENS = {
    "llava-1.5-7b": 576,
    "llava-next-7b": 2880,
    "qwen2-vl-7b": 1236,
}

# paper Table 3 SLO settings (seconds): (model, dataset) -> SLO
PAPER_SLOS = {
    ("llava-1.5-7b", "vizwiz"): SLO(8.0, 0.04),
    ("llava-1.5-7b", "textvqa"): SLO(0.25, 0.04),
    ("llava-1.5-7b", "mme"): SLO(0.25, 0.06),
    ("llava-1.5-7b", "pope"): SLO(0.25, 0.04),
    ("llava-1.5-7b", "textcaps"): SLO(0.25, 0.04),
    ("llava-next-7b", "vizwiz"): SLO(8.0, 0.12),
    ("llava-next-7b", "textvqa"): SLO(8.0, 0.12),
    ("llava-next-7b", "mme"): SLO(8.0, 0.14),
    ("llava-next-7b", "pope"): SLO(8.0, 0.06),
    ("llava-next-7b", "textcaps"): SLO(8.0, 0.08),
    ("qwen2-vl-7b", "vizwiz"): SLO(8.0, 0.14),
    ("qwen2-vl-7b", "textvqa"): SLO(1.0, 0.12),
    ("qwen2-vl-7b", "mme"): SLO(1.0, 0.14),
    ("qwen2-vl-7b", "pope"): SLO(1.0, 0.04),
    ("qwen2-vl-7b", "textcaps"): SLO(1.0, 0.14),
    ("text", "text"): SLO(1.0, 0.05),
}


def slo_for(model: str, dataset: str) -> SLO:
    return PAPER_SLOS.get((model, dataset), SLO(1.0, 0.08))


def make_requests(profile: WorkloadProfile, *, rate: float, n: int,
                  image_tokens_per_image: int, slo: SLO,
                  seed: int = 0) -> list[Request]:
    """Poisson arrival process at ``rate`` req/s; fixed output lengths
    (paper methodology: max_tokens + ignore_eos for engine-fair loads)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n):
        t += rng.exponential(1.0 / rate)
        n_img, prompt, gen = profile.sample_lengths(rng)
        out.append(Request(
            rid=rid, arrival=t, n_images=n_img,
            image_tokens=n_img * image_tokens_per_image,
            prompt_tokens=prompt, max_new_tokens=gen, slo=slo))
    return out
