"""Streaming engine API (DESIGN.md §13).

``Engine`` turns :class:`~repro.engine.server.HydraServer` — a step-driven
continuous-batching scheduler since the `step()` extraction — into an
open-loop serving surface:

  generate(prompt, media=..., sampling=..., slo=...)  ->  RequestStream
      per-request stream of StreamEvents: the first token, token deltas,
      and a finish event carrying the reason ("length" | "stop" | "abort")
  submit() / events()     the same, split into enqueue + stream halves;
                          submit is legal at ANY time — requests join the
                          live loop (continuous batching), they are not
                          collected up front
  abort(rid)              cancel at any stage; the request's KV/image
                          blocks are freed on whichever instance holds it
                          (a retired/unknown rid is a no-op returning False)
  step()                  drive one scheduler iteration by hand
  start() / close()       background serve loop (used by the HTTP front
                          and the open-loop benchmark); ``close()``
                          gracefully drains in-flight requests with a
                          configurable timeout, then aborts the remainder
                          and reclaims their blocks

Two driving modes share one code path:

  step-driven   no thread: iterating a ``RequestStream`` (or calling
                ``step()``) advances the whole engine, so every in-flight
                request progresses while you consume one stream
  threaded      ``start()`` spawns the serve loop; streams then block on a
                condition variable until their events arrive

All public methods are thread-safe: a single re-entrant lock serializes
scheduler iterations with submissions/aborts, so requests and cancels land
*between* iterations, never inside one.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Iterator, Optional

import numpy as np

from repro.core.request import SLO, SamplingParams, StreamEvent
from repro.engine.server import HydraServer, ServeItem


class RequestStream:
    """Iterable over one request's StreamEvents (ends after "finish")."""

    def __init__(self, engine: "Engine", rid: int):
        self.engine = engine
        self.rid = rid

    def __iter__(self) -> Iterator[StreamEvent]:
        return self.engine.events(self.rid)

    def tokens(self) -> list:
        """Drain the stream; returns the full token-id list."""
        for _ in self:
            pass
        return list(self.engine.result(self.rid).generated)

    def abort(self) -> bool:
        return self.engine.abort(self.rid)


class Engine:
    """Streaming facade over a live ``HydraServer`` (see module docstring)."""

    def __init__(self, cfg, params, disagg, **server_kw):
        self.server = HydraServer(cfg, params, disagg, **server_kw)
        self.server.on_event = self._on_event
        self._cv = threading.Condition(threading.RLock())
        self._queues: dict[int, deque] = {}
        self._finished: set[int] = set()
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = False

    # ------------------------------------------------------------------
    # event plumbing (called from inside server.step, under the lock)
    # ------------------------------------------------------------------
    def _on_event(self, ev: StreamEvent):
        q = self._queues.get(ev.rid)
        if q is not None:
            q.append(ev)
        if ev.kind == "finish":
            self._finished.add(ev.rid)
        self._cv.notify_all()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, prompt, *, media=None,
               sampling: Optional[SamplingParams] = None,
               slo: Optional[SLO] = None,
               max_new_tokens: Optional[int] = None) -> int:
        """Enqueue a request into the live loop; returns its rid.  The
        arrival timestamp is *now* on the engine clock (open-loop)."""
        with self._cv:
            rid = self.server.submit(np.asarray(prompt), media=media,
                                     sampling=sampling, slo=slo,
                                     max_new_tokens=max_new_tokens,
                                     arrival=self.server.now())
            self._queues[rid] = deque()
            self._cv.notify_all()
            return rid

    def generate(self, prompt, *, media=None,
                 sampling: Optional[SamplingParams] = None,
                 slo: Optional[SLO] = None,
                 max_new_tokens: Optional[int] = None) -> RequestStream:
        rid = self.submit(prompt, media=media, sampling=sampling, slo=slo,
                          max_new_tokens=max_new_tokens)
        return RequestStream(self, rid)

    def abort(self, rid: int) -> bool:
        """Cancel ``rid`` wherever it is (queued / encode / prefill /
        decode); frees its cache blocks and emits the finish event."""
        with self._cv:
            return self.server.abort(rid)

    def step(self) -> bool:
        """One scheduler iteration (step-driven mode)."""
        with self._cv:
            return self.server.step()

    def result(self, rid: int) -> ServeItem:
        """The request's ServeItem (tokens so far, Request with metrics)."""
        return self.server.items[rid]

    def cache_stats(self) -> dict:
        """Prefix/encode cache hit rates + COW/eviction counters (all zero
        unless the server was built with ``prefix_cache=True``)."""
        with self._cv:
            return self.server.cache_stats()

    def release(self, rid: int):
        """Drop a finished (or aborted) request's retained state — its
        event queue, finish marker, and ServeItem.  Long-lived servers
        (the HTTP front) call this after responding so memory stays
        bounded; ``result``/``events`` are invalid for the rid afterwards.
        """
        with self._cv:
            self._queues.pop(rid, None)
            self._finished.discard(rid)
            self.server.items.pop(rid, None)

    def events(self, rid: int) -> Iterator[StreamEvent]:
        """Yield ``rid``'s StreamEvents until (and including) "finish".

        Without a serve thread, this *drives* the engine: each pass with an
        empty queue runs one ``step()``, so all in-flight requests advance
        while one stream is consumed (capacity-deadlock stall guard
        included, same as ``HydraServer.run``).
        """
        q = self._queues[rid]
        stalled = 0
        while True:
            ev = None
            with self._cv:
                if not q and self._thread is not None:
                    self._cv.wait(timeout=0.1)
                if q:
                    ev = q.popleft()
                done = rid in self._finished
            if ev is None:
                if done:
                    return  # finish already consumed elsewhere
                if self._thread is None:
                    if self.step():
                        stalled = 0
                    else:
                        with self._cv:
                            candidate = self.server.deadlock_candidate()
                        if candidate:
                            stalled += 1
                            if stalled >= 100:
                                raise RuntimeError(
                                    self.server.stall_diagnosis()[1])
                        else:
                            stalled = 0
                            time.sleep(0.001)  # future work: wait
                continue
            yield ev
            if ev.kind == "finish":
                return

    # ------------------------------------------------------------------
    # loop control
    # ------------------------------------------------------------------
    def start(self) -> "Engine":
        """Spawn the background serve loop (threaded mode)."""
        if self._thread is None:
            self._stop_flag = False
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="hydra-engine")
            self._thread.start()
        return self

    def _loop(self):
        while not self._stop_flag:
            if not self.step():
                time.sleep(0.001)

    def _live_rids(self) -> list:
        """Rids submitted but not yet finished (caller holds the lock)."""
        return [rid for rid, it in self.server.items.items()
                if not it.req.done]

    def close(self, drain_timeout: Optional[float] = 5.0):
        """Graceful shutdown: keep stepping until every in-flight request
        finishes or ``drain_timeout`` (seconds) elapses, then abort the
        stragglers — freeing their cache blocks and emitting "abort" finish
        events so open streams terminate — and stop the background loop.
        ``drain_timeout=0`` aborts immediately; ``None`` waits forever."""
        deadline = None if drain_timeout is None \
            else time.monotonic() + drain_timeout
        while True:
            with self._cv:
                live = self._live_rids()
            if not live:
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
            if self._thread is None:
                with self._cv:
                    worked = self.server.step()
                if not worked:
                    time.sleep(0.001)
            else:
                time.sleep(0.01)   # the serve thread is doing the work
        with self._cv:
            for rid in self._live_rids():
                self.server.abort(rid)
        self._stop_flag = True
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def wait(self, rids, timeout: Optional[float] = None) -> bool:
        """Threaded mode: block until every rid finished.  Returns False on
        timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not all(r in self._finished for r in rids):
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._cv.wait(timeout=0.2 if left is None
                              else min(left, 0.2))
        return True

    def drain(self, max_iters: int = 10_000):
        """Step-driven mode: step until the server is idle (the streaming
        analogue of ``HydraServer.run``, stall guard included)."""
        stalled = 0
        for _ in range(max_iters):
            with self._cv:
                if self.server.idle():
                    return
                worked = self.server.step()
                if worked:
                    stalled = 0
                    continue
                candidate = self.server.deadlock_candidate()
            if candidate:
                stalled += 1
                if stalled >= 100:
                    raise RuntimeError(self.server.stall_diagnosis()[1])
            else:
                stalled = 0
                time.sleep(0.001)
        raise RuntimeError("drain: max_iters exceeded")
