"""Fault model for the real-execution serving stack (DESIGN.md §15).

Disaggregated EPD serving multiplies failure domains: a single dead or
wedged instance strands every request mid-pipeline and every migrated KV
block on it.  This module holds the *leaf* pieces of the fault-tolerance
layer — it imports nothing from the engine so every other engine module
can depend on it:

  FaultPlan / FaultEvent   seeded, deterministic fault injection keyed on
                           the scheduler iteration counter: instance
                           crashes, step stalls (a wedged device), cache
                           allocation failures, and dropped / corrupted
                           E->P / P->D transfers
  TransferError            typed failure of a cache transfer (dropped,
                           corrupt-checksum, destination OOM, timeout) —
                           the migration path retries these with bounded
                           backoff before falling back to journal replay
  AdmissionError           typed rejection of a submit under deadline-aware
                           load shedding (capacity durably degraded)
  RequestJournal           the minimal per-request durable record (prompt,
                           media content-hashes, sampling seed; accepted
                           tokens live in the ServeItem) that makes a
                           stranded request re-dispatchable with bit-exact
                           greedy/seeded continuation
  payload_checksum         end-to-end checksum over a transfer payload
                           (numpy / jnp arrays or nested dict trees), how
                           corrupted transfers are *detected*

Injection is deterministic by construction: a plan is a sorted set of
(iteration, kind, instance) events, and ``FaultPlan.random`` derives one
from a seed, so a failing fault sweep reproduces from its seed alone.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# fault kinds
CRASH = "crash"          # instance dies: all device state lost
STALL = "stall"          # instance wedges for `arg` iterations (no progress)
ALLOC = "alloc"          # cache allocations fail for `arg` iterations
DROP = "drop"            # migration payload lost in flight
CORRUPT = "corrupt"      # migration payload corrupted in flight
KINDS = (CRASH, STALL, ALLOC, DROP, CORRUPT)


class TransferError(RuntimeError):
    """A cache transfer failed in a retryable way.  ``kind`` is one of
    "drop" | "corrupt" | "oom" | "timeout"."""

    def __init__(self, kind: str, msg: str):
        super().__init__(msg)
        self.kind = kind


class AdmissionError(RuntimeError):
    """Submit rejected: capacity is durably degraded and the request could
    never meet its deadline (deadline-aware load shedding, DESIGN.md §15).
    Typed so fronts can map it to a proper 503 instead of queueing the
    request forever."""


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault.  ``iteration`` counts *productive* scheduler
    iterations (steps where some instance had pending work — idle spins
    between Poisson arrivals don't advance fault time, so plans stay
    meaningful under open-loop load).  ``iid`` targets one instance; -1
    matches any.  ``arg`` is the window length in iterations for
    stall/alloc, and the number of failing transfer *attempts* for
    drop/corrupt (1 = first attempt fails, the retry succeeds)."""
    iteration: int
    kind: str
    iid: int = -1
    arg: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {KINDS}")


@dataclass
class RequestJournal:
    """Minimal durable record for failure recovery (DESIGN.md §15): enough
    to re-dispatch a stranded request to a surviving instance and replay it
    to a bit-exact continuation.  The original prompt is kept verbatim (the
    live ServeItem.prompt is rewritten with replay context on recovery);
    media is identified by content hash so the host-side copy can be
    integrity-checked before re-encoding; the resolved sampling seed plus
    the accepted-token count pin the per-lane PRNG stream."""
    prompt: np.ndarray          # original prompt token ids (copy)
    media_hashes: tuple = ()    # per-image blake2b content hashes
    seed: int = 0               # resolved sampling seed


class FaultPlan:
    """A deterministic schedule of injected faults, queried by the server
    each scheduler iteration.  Build one explicitly from events, randomly
    from a seed (``FaultPlan.random``), or from a CLI spec string
    (``FaultPlan.parse``)."""

    def __init__(self, events=()):
        self.events = tuple(sorted(events, key=lambda e: (e.iteration,
                                                          e.kind, e.iid)))
        self._crashed: set = set()   # one-shot crash events already fired

    def __repr__(self):
        return f"FaultPlan({list(self.events)!r})"

    def __bool__(self):
        return bool(self.events)

    # ------------------------------------------------------------------
    def _match(self, ev: FaultEvent, iid: int) -> bool:
        return ev.iid < 0 or ev.iid == iid

    def crash(self, iteration: int, iid: int) -> bool:
        """True exactly once per crash event, at (or after — an instance
        that was idle at the chosen iteration still dies) its iteration."""
        for i, ev in enumerate(self.events):
            if ev.kind == CRASH and self._match(ev, iid) \
                    and iteration >= ev.iteration and i not in self._crashed:
                self._crashed.add(i)
                return True
        return False

    def _in_window(self, kind: str, iteration: int, iid: int) -> bool:
        return any(ev.kind == kind and self._match(ev, iid)
                   and ev.iteration <= iteration < ev.iteration + max(ev.arg, 1)
                   for ev in self.events)

    def stalled(self, iteration: int, iid: int) -> bool:
        """Instance ``iid`` is wedged this iteration (builds batches but
        executes nothing — the no-progress failure mode)."""
        return self._in_window(STALL, iteration, iid)

    def alloc_fail(self, iteration: int, iid: int) -> bool:
        """Cache allocations on ``iid`` fail this iteration."""
        return self._in_window(ALLOC, iteration, iid)

    def transfer_fault(self, iteration: int, attempt: int) -> Optional[str]:
        """Fault applied to a migration attempted this iteration, or None.
        ``attempt`` indexes retries: an event only affects attempts below
        its ``arg``, so ``arg=1`` exercises retry-and-succeed while a large
        ``arg`` exhausts the retry budget and forces journal replay."""
        for ev in self.events:
            if ev.kind in (DROP, CORRUPT) and ev.iteration <= iteration \
                    and attempt < ev.arg:
                # windows are open-ended on attempts, not iterations: a
                # migration deferred past the chosen iteration still hits
                if iteration < ev.iteration + 1:
                    return ev.kind
        return None

    # ------------------------------------------------------------------
    @classmethod
    def random(cls, seed: int, *, horizon: int, iids,
               p_crash: float = 0.0, p_stall: float = 0.02,
               p_alloc: float = 0.02, p_transfer: float = 0.02,
               max_crashes: int = 0, stall_len: int = 3) -> "FaultPlan":
        """Derive a plan from a seed: per (iteration, instance) Bernoulli
        draws for stalls/allocation failures/transfer faults, plus up to
        ``max_crashes`` crashes at uniform iterations (never more than
        len(iids) - 1, so at least one instance survives)."""
        rng = np.random.default_rng(seed)
        iids = list(iids)
        events = []
        n_crash = min(int(max_crashes), max(len(iids) - 1, 0))
        if n_crash and p_crash > 0:
            victims = rng.choice(len(iids), size=n_crash, replace=False)
            for v in victims:
                if rng.random() < p_crash:
                    events.append(FaultEvent(
                        int(rng.integers(1, max(horizon, 2))), CRASH,
                        iid=iids[int(v)]))
        for it in range(1, horizon + 1):
            for iid in iids:
                if rng.random() < p_stall:
                    events.append(FaultEvent(it, STALL, iid=iid,
                                             arg=int(rng.integers(
                                                 1, stall_len + 1))))
                if rng.random() < p_alloc:
                    events.append(FaultEvent(it, ALLOC, iid=iid))
            if rng.random() < p_transfer:
                events.append(FaultEvent(
                    it, DROP if rng.random() < 0.5 else CORRUPT))
        return cls(events)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """CLI knob: comma-separated ``kind@iteration[:iid][+arg]`` parts,
        e.g. ``crash@100:1,stall@40:0+5,drop@60,alloc@80:2``."""
        events = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            m = re.fullmatch(
                r"(\w+)@(\d+)(?::(-?\d+))?(?:\+(\d+))?", part)
            if not m:
                raise ValueError(
                    f"bad fault spec {part!r} "
                    f"(expected kind@iteration[:iid][+arg])")
            events.append(FaultEvent(int(m.group(2)), m.group(1),
                                     iid=int(m.group(3) or -1),
                                     arg=int(m.group(4) or 1)))
        return cls(events)


# ---------------------------------------------------------------------------
# transfer checksums (corruption *detection*; injection lives in the plan)
# ---------------------------------------------------------------------------
def _walk_arrays(payload, visit):
    """Deterministic traversal of a transfer payload: arrays directly, dict
    trees in sorted key order, scalars by repr."""
    if isinstance(payload, dict):
        for k in sorted(payload, key=str):
            visit(str(k).encode())
            _walk_arrays(payload[k], visit)
    elif hasattr(payload, "shape"):
        a = np.ascontiguousarray(np.asarray(payload))
        visit(str((a.shape, a.dtype.str)).encode())
        visit(a.tobytes())
    else:
        visit(repr(payload).encode())


def payload_checksum(payload) -> bytes:
    """End-to-end checksum of one store's transfer payload."""
    h = hashlib.blake2b(digest_size=16)
    _walk_arrays(payload, h.update)
    return h.digest()


def corrupt_payload(payload):
    """Return a bit-flipped copy of ``payload`` (the simulated wire
    corruption a checksum must catch).  Dict trees corrupt their first
    array leaf; empty payloads come back unchanged."""
    if isinstance(payload, dict):
        for k in sorted(payload, key=str):
            flipped = corrupt_payload(payload[k])
            if flipped is not payload[k]:
                out = dict(payload)
                out[k] = flipped
                return out
        return payload
    if hasattr(payload, "shape"):
        a = np.array(np.asarray(payload), copy=True)
        if a.size:
            flat = a.view(np.uint8).reshape(-1)
            flat[0] ^= 0xFF
            return a
    return payload
