"""Paged cache management (paper §4.5) + prefix/content block sharing
(DESIGN.md §14).

Centralized, paged memory for both the KV cache and the image-token cache
with a *unified* management + transfer interface: the image cache is a
one-layer, single-tensor cache (block size = one image), the KV cache is a
multi-layer, two-tensor cache (block size 16).  Fixed-size recurrent state
(SSM/MLA-conv) lives in a per-request StateStore with the same transfer
interface, so migration code is cache-kind-agnostic.

Two storage backends share the layout ``[T, L, num_blocks, bs, width]`` and
the full transfer surface:

  PagedCache        host numpy — prefill staging, migration endpoints
  DevicePagedCache  jnp device arrays — the decode hot path reads pages
                    through the Pallas paged-attention kernel and appends
                    via the fused cache-write kernel without ever copying
                    the cache to the host (DESIGN.md §11)

Block sharing (``sharing=True``): every block carries a refcount equal to
its occurrences across block tables.  Full blocks register in a
hash-of-key-prefix chain index; a later request whose key stream matches a
registered chain adopts those blocks (``probe_prefix``/``take_prefix``)
instead of recomputing them.  All writes go through ``_prepare_write``,
which copy-on-writes any shared block before the scatter lands, so a
sharer can never corrupt another request's pages.  Blocks whose refcount
reaches zero but whose content is still indexed park in an LRU *evictable*
pool — reclaimed (and unindexed) only when the allocator runs dry.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.engine.faults import (TransferError, corrupt_payload,
                                 payload_checksum)


class BlockAllocator:
    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self.free = list(range(num_blocks - 1, -1, -1))

    def alloc(self, n: int) -> list:
        if n > len(self.free):
            raise MemoryError(f"cache OOM: need {n}, free {len(self.free)}")
        return [self.free.pop() for _ in range(n)]

    def release(self, blocks: list):
        self.free.extend(blocks)

    @property
    def n_free(self) -> int:
        return len(self.free)


@dataclass
class PagedCacheSpec:
    n_tensors: int       # 2 for KV (k+v), 1 for image tokens
    n_layers: int
    block_size: int      # tokens per block (16 KV / one image for media)
    width: int           # per-token feature width
    num_blocks: int
    dtype: object = np.float32


def _mix(prev: int, key_block: tuple) -> int:
    """Chain-hash one block's key slice onto the running prefix hash.

    Python's tuple/int hashing is deterministic within a process (ints are
    not salted), which is the lifetime of a cache.  Production would use a
    keyed cryptographic hash; collisions here mean silent false sharing.
    """
    return hash((prev, key_block))


class PagedCacheBase:
    """Shared block-table bookkeeping for both storage backends.

    With ``sharing`` enabled the allocator is refcount-aware: ``free(rid)``
    *releases references* rather than blocks, and full blocks register in
    the prefix index so later requests can adopt them.
    """

    def __init__(self, spec: PagedCacheSpec, *, sharing: bool = False):
        self.spec = spec
        self.allocator = BlockAllocator(spec.num_blocks)
        self.tables: dict[int, list] = {}    # rid -> [block ids]
        self.lengths: dict[int, int] = {}    # rid -> tokens stored
        self.sharing = sharing
        # --- sharing state (inert when sharing is off) ---
        self.refcount = [0] * spec.num_blocks
        self.hash_block: dict[int, int] = {}   # chain hash -> block id
        self.block_hash: dict[int, int] = {}   # block id -> chain hash
        self.evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU
        self.keys: dict[int, list] = {}        # rid -> live key stream
        self.roots: dict[int, int] = {}        # rid -> chain root seed
        self._chain: dict[int, tuple] = {}     # rid -> (n_blocks_hashed, h)
        self.n_evictions = 0
        self.n_cow = 0
        # fault injection (DESIGN.md §15): when > 0, the next that-many
        # allocations raise MemoryError — the server sets this for one
        # scheduler iteration to exercise the batch-recovery path
        self.fail_alloc = 0

    # ------------------------------------------------------------------
    # allocation / release (refcount-aware)
    # ------------------------------------------------------------------
    @property
    def available_blocks(self) -> int:
        """Blocks obtainable right now: truly free + evictable cached."""
        return self.allocator.n_free + len(self.evictable)

    def _alloc(self, n: int) -> list:
        """Allocate ``n`` blocks at refcount 1, evicting LRU cached blocks
        (and dropping their index entries) when the free list runs dry."""
        if self.fail_alloc > 0:
            self.fail_alloc -= 1
            raise MemoryError("injected allocation failure")
        while self.allocator.n_free < n and self.evictable:
            b, _ = self.evictable.popitem(last=False)
            h = self.block_hash.pop(b, None)
            if h is not None:
                self.hash_block.pop(h, None)
            self.allocator.release([b])
            self.n_evictions += 1
        blocks = self.allocator.alloc(n)
        for b in blocks:
            self.refcount[b] = 1
        return blocks

    def _decref(self, blocks: list):
        dead = []
        for b in blocks:
            rc = self.refcount[b] = self.refcount[b] - 1
            if rc < 0:
                raise AssertionError(f"double free of block {b}")
            if rc == 0:
                if b in self.block_hash:
                    self.evictable[b] = None       # park: content reusable
                    self.evictable.move_to_end(b)
                else:
                    dead.append(b)
        if dead:
            self.allocator.release(dead)

    def _ensure_capacity(self, rid: int, n_tokens: int):
        bs = self.spec.block_size
        table = self.tables.setdefault(rid, [])
        self.lengths.setdefault(rid, 0)
        need_blocks = -(-n_tokens // bs)
        if need_blocks > len(table):
            table.extend(self._alloc(need_blocks - len(table)))

    def can_fit(self, n_tokens: int) -> bool:
        return -(-n_tokens // self.spec.block_size) <= self.available_blocks

    def free(self, rid: int):
        """Release the request's *references*.  A shared block survives in
        other tables; an indexed refcount-zero block parks in the evictable
        pool; everything else returns to the allocator.  This is the single
        release path for every retire/abort/migrate site (DESIGN.md §14)."""
        blocks = self.tables.pop(rid, [])
        self.lengths.pop(rid, None)
        self.keys.pop(rid, None)
        self.roots.pop(rid, None)
        self._chain.pop(rid, None)
        self._decref(blocks)

    # ------------------------------------------------------------------
    # prefix index: probe / adopt / register
    # ------------------------------------------------------------------
    def set_keys(self, rid: int, keys: list, root: int = 0):
        """Bind the request's *live* key stream (token ids / media keys —
        the caller keeps appending to the same list as decode proceeds) so
        commits can register completed blocks lazily."""
        self.keys[rid] = keys
        self.roots[rid] = root

    def probe_prefix(self, keys: list, root: int, limit: int) -> int:
        """Longest indexed prefix of ``keys`` (whole blocks), capped at
        ``limit`` tokens.  Pure lookup: no refcounts move."""
        if not self.sharing or limit <= 0:
            return 0
        bs = self.spec.block_size
        h, n = root, 0
        while n + bs <= len(keys) and n < limit:
            h2 = _mix(h, tuple(keys[n:n + bs]))
            if h2 not in self.hash_block:
                break
            h = h2
            n += bs
        return min(n, limit)

    def take_prefix(self, rid: int, matched: int, keys: list, root: int):
        """Adopt the first ``matched`` tokens' blocks (as returned by
        ``probe_prefix``): incref each chain block into ``rid``'s table.
        ``matched`` may end mid-block (the hit cap); the partial tail block
        is adopted whole and copy-on-written if ``rid`` ever writes it."""
        if matched <= 0:
            return
        if self.tables.get(rid):
            raise AssertionError(f"take_prefix on non-empty table rid={rid}")
        bs = self.spec.block_size
        n_blocks = -(-matched // bs)
        h = root
        blocks = []
        n_full_hash = (0, root)
        for k in range(n_blocks):
            h = _mix(h, tuple(keys[k * bs:(k + 1) * bs]))
            b = self.hash_block[h]
            if self.refcount[b] == 0:
                self.evictable.pop(b)              # revive from the pool
            self.refcount[b] += 1
            blocks.append(b)
            if (k + 1) * bs <= matched:
                n_full_hash = (k + 1, h)
        self.tables[rid] = blocks
        self.lengths[rid] = matched
        # chain resumes after the fully-covered blocks; the partial tail
        # re-hashes with rid's OWN keys once rid fills it
        self._chain[rid] = n_full_hash

    def _maybe_register(self, rid: int):
        """Register every newly-completed full block of ``rid`` in the
        prefix index (called from every commit path).  No-op without keys
        or when sharing is off."""
        if not self.sharing:
            return
        keys = self.keys.get(rid)
        if keys is None:
            return
        bs = self.spec.block_size
        table = self.tables.get(rid, [])
        n_full = self.lengths.get(rid, 0) // bs
        k, h = self._chain.get(rid, (0, self.roots.get(rid, 0)))
        while k < n_full and (k + 1) * bs <= len(keys) and k < len(table):
            h = _mix(h, tuple(keys[k * bs:(k + 1) * bs]))
            b = table[k]
            if h not in self.hash_block and b not in self.block_hash:
                self.hash_block[h] = b
                self.block_hash[b] = h
            k += 1
        self._chain[rid] = (k, h)

    # ------------------------------------------------------------------
    # copy-on-write
    # ------------------------------------------------------------------
    def _prepare_write(self, rid: int, start: int, n: int):
        """Make token positions [start, start+n) of ``rid`` safely writable:
        any touched block that is shared (refcount > 1) is duplicated first
        (COW) so the scatter cannot land in another request's pages; a
        sole-owned but still-indexed block is unindexed instead (cheaper —
        its registered content is about to diverge)."""
        if n <= 0 or not self.sharing:
            return
        bs = self.spec.block_size
        table = self.tables.get(rid, [])
        pairs = []
        for k in range(start // bs, (start + n - 1) // bs + 1):
            if k >= len(table):
                break
            b = table[k]
            if self.refcount[b] > 1:
                [nb] = self._alloc(1)
                table[k] = nb
                pairs.append((b, nb))
                self.refcount[b] -= 1     # still > 0: other holders remain
                self.n_cow += 1
            elif b in self.block_hash:
                h = self.block_hash.pop(b)
                self.hash_block.pop(h, None)
        if pairs:
            self._copy_blocks(pairs)

    def _copy_blocks(self, pairs: list):
        raise NotImplementedError

    def _slot_arrays(self, rid: int, start: int, n: int):
        """(block ids, in-block offsets) for token positions [start, start+n)."""
        pos = np.arange(start, start + n)
        bs = self.spec.block_size
        table = np.asarray(self.tables.get(rid, []), np.int64)
        return table[pos // bs], pos % bs

    def row_slots(self, rid: int, start: int, n: int) -> np.ndarray:
        """Within-plane row slots (``block * bs + offset``) for token
        positions [start, start+n) — the device-side gather/scatter
        addresses of those tokens."""
        blks, offs = self._slot_arrays(rid, start, n)
        return (blks * self.spec.block_size + offs).astype(np.int32)

    # ------------------------------------------------------------------
    # migration transfer interface (paper §4.3, unified for KV/image)
    # ------------------------------------------------------------------
    def export_control(self, rid: int) -> dict:
        """Step 1: control info (page table metadata), no bulk data."""
        return {"rid": rid, "length": self.lengths.get(rid, 0),
                "blocks": list(self.tables.get(rid, []))}

    def nbytes(self, rid: int) -> int:
        s = self.spec
        return (len(self.tables.get(rid, [])) * s.n_tensors * s.n_layers *
                s.block_size * s.width * np.dtype(s.dtype).itemsize)


class PagedCache(PagedCacheBase):
    """Host (numpy) paged cache.  Storage: [T, L, num_blocks, bs, width]."""

    def __init__(self, spec: PagedCacheSpec, *, sharing: bool = False):
        super().__init__(spec, sharing=sharing)
        s = spec
        self.data = np.zeros((s.n_tensors, s.n_layers, s.num_blocks,
                              s.block_size, s.width), s.dtype)

    def _copy_blocks(self, pairs: list):
        src = [a for a, _ in pairs]
        dst = [b for _, b in pairs]
        self.data[:, :, dst] = self.data[:, :, src]

    def append(self, rid: int, values: np.ndarray):
        """values: [T(=n_tensors), L, n_new, width] appended at the tail."""
        n_new = values.shape[2]
        start = self.lengths.get(rid, 0)
        self._ensure_capacity(rid, start + n_new)
        self._prepare_write(rid, start, n_new)
        blks, offs = self._slot_arrays(rid, start, n_new)
        self.data[:, :, blks, offs] = np.asarray(values)
        self.lengths[rid] = start + n_new
        self._maybe_register(rid)

    def gather(self, rid: int) -> np.ndarray:
        """Contiguous [n_tensors, L, length, width] view-copy."""
        n = self.lengths.get(rid, 0)
        blks, offs = self._slot_arrays(rid, 0, n)
        return self.data[:, :, blks, offs]

    def read_blocks(self, rid: int) -> np.ndarray:
        """Step 3: source-side bulk read of the request's blocks."""
        table = self.tables.get(rid, [])
        return self.data[:, :, table].copy()

    def import_blocks(self, rid: int, length: int, payload: np.ndarray):
        """Step 2+3 target side: allocate pages, then write pulled blocks."""
        n_blocks = payload.shape[2]
        blocks = self._alloc(n_blocks)
        self.tables[rid] = blocks
        self.lengths[rid] = length
        self.data[:, :, blocks] = np.asarray(payload)
        self._maybe_register(rid)


_DEVICE_APPEND = None
_DEVICE_COPY = None


def _device_append(data, rows, slot_vec):
    """Jitted pool-donating append: scatter ``rows`` at ``slot_vec`` into the
    flattened [T*L*NB, bs, w] view of ``data`` and return it, in place."""
    global _DEVICE_APPEND
    if _DEVICE_APPEND is None:
        import jax
        from repro.kernels.cache_write.ops import cache_write

        def impl(data, rows, slot_vec):
            T, L, NB, bs, w = data.shape
            flat = data.reshape(T * L * NB, bs, w)
            flat = cache_write(flat, rows, slot_vec, use_kernel=False)
            return flat.reshape(T, L, NB, bs, w)

        _DEVICE_APPEND = jax.jit(impl, donate_argnums=(0,))
    return _DEVICE_APPEND(data, rows, slot_vec)


def _device_copy(data, src, dst):
    """Jitted pool-donating block duplication (the COW copy): block columns
    ``src`` land at ``dst`` in place — an eager ``.at[].set`` would copy the
    whole pool buffer instead."""
    global _DEVICE_COPY
    if _DEVICE_COPY is None:
        import jax

        def impl(data, src, dst):
            return data.at[:, :, dst].set(data[:, :, src])

        _DEVICE_COPY = jax.jit(impl, donate_argnums=(0,))
    return _DEVICE_COPY(data, src, dst)


class DevicePagedCache(PagedCacheBase):
    """Device-resident paged cache: block storage lives as one jnp array of
    the same ``[T, L, num_blocks(+1), bs, width]`` layout, so the decode hot
    path can hand pages + block tables straight to the Pallas paged-attention
    / cache-write kernels without any host round-trip.

    One extra *scratch* block (physical index ``num_blocks``) absorbs the
    writes and reads of padded batch lanes introduced by batch-size
    bucketing; the allocator never hands it out.
    """

    def __init__(self, spec: PagedCacheSpec, *, sharing: bool = False):
        super().__init__(spec, sharing=sharing)
        import jax.numpy as jnp  # deferred: host-only tools never pay for jax
        self._jnp = jnp
        s = spec
        self.data = jnp.zeros((s.n_tensors, s.n_layers, s.num_blocks + 1,
                               s.block_size, s.width), s.dtype)

    @property
    def scratch_block(self) -> int:
        return self.spec.num_blocks

    def _copy_blocks(self, pairs: list):
        src = np.asarray([a for a, _ in pairs], np.int32)
        dst = np.asarray([b for _, b in pairs], np.int32)
        self.data = _device_copy(self.data, self._jnp.asarray(src),
                                 self._jnp.asarray(dst))

    # -- host-interop append/gather (prefill staging, migration) ----------
    def append(self, rid: int, values):
        """values: [T, L, n_new, width] (np or jnp) appended at the tail.

        Goes through the buffer-donating ``cache_write`` op (ref backend)
        under a jit that owns the pool exclusively: one fused in-place
        scatter instead of copying the whole pool.  (The reshape must stay
        inside the jit — an eager reshape would create a second buffer
        handle and defeat donation.)
        """
        jnp = self._jnp
        n_new = values.shape[2]
        start = self.lengths.get(rid, 0)
        self._ensure_capacity(rid, start + n_new)
        self._prepare_write(rid, start, n_new)
        blks, offs = self._slot_arrays(rid, start, n_new)
        s = self.spec
        T, L, NB = s.n_tensors, s.n_layers, s.num_blocks + 1
        bs = s.block_size
        plane = (np.arange(T)[:, None] * L + np.arange(L)[None, :]) * (NB * bs)
        slot_vec = (plane[:, :, None] + (blks * bs + offs)[None, None, :])
        rows = jnp.asarray(values, self.data.dtype).reshape(
            T * L * n_new, s.width)
        self.data = _device_append(self.data, rows,
                                   jnp.asarray(slot_vec.reshape(-1),
                                               jnp.int32))
        self.lengths[rid] = start + n_new
        self._maybe_register(rid)

    def gather(self, rid: int):
        """Contiguous [n_tensors, L, length, width] *device* array."""
        n = self.lengths.get(rid, 0)
        blks, offs = self._slot_arrays(rid, 0, n)
        return self.data[:, :, blks, offs]

    def read_blocks(self, rid: int):
        table = np.asarray(self.tables.get(rid, []), np.int64)
        return self.data[:, :, table]

    def import_blocks(self, rid: int, length: int, payload):
        n_blocks = payload.shape[2]
        blocks = self._alloc(n_blocks)
        self.tables[rid] = blocks
        self.lengths[rid] = length
        self.data = self.data.at[:, :, np.asarray(blocks, np.int64)].set(
            self._jnp.asarray(payload, self.data.dtype))
        self._maybe_register(rid)

    # -- decode hot path ---------------------------------------------------
    def prepare_decode(self, rids: list, batch_pad: int, pages_pad: int):
        """Per-step control tensors for the jitted paged decode.

        Allocates one-token headroom per request (copy-on-writing a shared
        tail block), then returns host int32 arrays (tiny; the bulk cache
        never moves):

          tables [batch_pad, pages_pad]  block table, scratch-padded
          slots  [batch_pad]             within-plane row slot (block*bs+off)
                                         of the token being appended
        Padded lanes point at the scratch block so their writes land off to
        the side and their (discarded) reads stay in bounds.
        """
        bs = self.spec.block_size
        scratch = self.scratch_block
        tables = np.full((batch_pad, pages_pad), scratch, np.int32)
        slots = np.full((batch_pad,), scratch * bs, np.int32)
        for b, rid in enumerate(rids):
            n = self.lengths.get(rid, 0)
            self._ensure_capacity(rid, n + 1)
            self._prepare_write(rid, n, 1)
            table = self.tables[rid]
            tables[b, :len(table)] = table
            slots[b] = table[n // bs] * bs + n % bs
        return tables, slots

    def commit_decode(self, rids: list):
        """Account the one token per request that the kernel just wrote."""
        for rid in rids:
            self.lengths[rid] = self.lengths.get(rid, 0) + 1
            self._maybe_register(rid)

    # -- batched chunked prefill -------------------------------------------
    def prepare_prefill(self, rids: list, n_new: list, batch_pad: int,
                        chunk_pad: int, pages_pad: int):
        """Per-chunk control tensors for the jitted batched prefill.

        Allocates ``n_new[i]``-token headroom per request (copy-on-writing
        any shared block the chunk lands in), then returns host int32
        arrays (tiny; the bulk cache never moves):

          tables [batch_pad, pages_pad]   block table, scratch-padded
          slots  [batch_pad, chunk_pad]   within-plane row slot of each
                                          chunk token being appended
        Padded lanes and padded chunk positions point at the scratch block
        so their writes land off to the side and their (discarded) reads
        stay in bounds.
        """
        bs = self.spec.block_size
        scratch = self.scratch_block
        tables = np.full((batch_pad, pages_pad), scratch, np.int32)
        slots = np.full((batch_pad, chunk_pad), scratch * bs, np.int32)
        for b, (rid, n) in enumerate(zip(rids, n_new)):
            start = self.lengths.get(rid, 0)
            self._ensure_capacity(rid, start + n)
            self._prepare_write(rid, start, n)
            table = self.tables[rid]
            tables[b, :len(table)] = table
            slots[b, :n] = self.row_slots(rid, start, n)
        return tables, slots

    def commit_prefill(self, rids: list, n_new: list):
        """Account the chunk tokens the kernel just wrote per request."""
        for rid, n in zip(rids, n_new):
            self.lengths[rid] = self.lengths.get(rid, 0) + n
            self._maybe_register(rid)


class StateStore:
    """Fixed-size per-request state (SSM state/conv, MLA rope cache, cross-KV)
    with the same export/import surface as PagedCache."""

    def __init__(self):
        self.store: dict[int, dict] = {}

    def put(self, rid: int, tree: dict):
        self.store[rid] = tree

    def get(self, rid: int) -> Optional[dict]:
        return self.store.get(rid)

    def free(self, rid: int):
        self.store.pop(rid, None)

    def export_control(self, rid: int) -> dict:
        return {"rid": rid, "keys": sorted(self.store.get(rid, {}).keys())}

    def read_blocks(self, rid: int) -> dict:
        return self.store.get(rid, {})

    def import_blocks(self, rid: int, payload: dict):
        self.store[rid] = payload

    def nbytes(self, rid: int) -> int:
        tree = self.store.get(rid, {})
        total = 0

        def walk(x):
            nonlocal total
            if isinstance(x, dict):
                for v in x.values():
                    walk(v)
            elif hasattr(x, "nbytes"):
                total += x.nbytes
        walk(tree)
        return total


def migrate_request(rid: int, src, dst, *, fault: Optional[str] = None,
                    timeout: Optional[float] = None) -> int:
    """Transactional pull-based migration (paper §4.3, hardened per
    DESIGN.md §15) over the unified interface.

    Three phases, so a failed transfer never strands the request:

    1. *read*: the source exports control info and bulk payloads for EVERY
       store, and each payload is checksummed end-to-end (blake2b) —
       StateStore payloads are snapshotted since ``read_blocks`` returns
       the live dict.
    2. *verify + import*: each payload is re-checksummed against its phase-1
       digest (detecting wire corruption) and imported at the destination.
       Any failure — checksum mismatch, destination OOM, wall-clock timeout
       — rolls back every import already landed and raises a typed
       :class:`~repro.engine.faults.TransferError`; the SOURCE copy is
       untouched, so the caller can retry against the same or another
       destination.
    3. *release*: only after every store imported does the source release
       its references (blocks shared with other requests survive).

    ``fault`` injects a wire failure for this attempt ("drop" loses the
    payload before import; "corrupt" bit-flips one payload so the checksum
    must catch it).  ``timeout`` bounds the whole transfer in seconds.
    Returns bytes moved.
    """
    t0 = time.monotonic()
    staged = []           # (s_cache, d_cache, ctrl, payload, checksum)
    moved = 0
    for s_cache, d_cache in zip(src, dst):                   # phase 1: read
        ctrl = s_cache.export_control(rid)
        payload = s_cache.read_blocks(rid)
        if not isinstance(s_cache, PagedCacheBase):
            payload = dict(payload)        # snapshot the live StateStore dict
        moved += s_cache.nbytes(rid)
        staged.append([s_cache, d_cache, ctrl, payload,
                       payload_checksum(payload)])
    if fault == "drop":
        raise TransferError("drop",
                            f"rid={rid}: transfer payload lost in flight")
    if fault == "corrupt" and staged:
        staged[0][3] = corrupt_payload(staged[0][3])
    if timeout is not None and time.monotonic() - t0 > timeout:
        raise TransferError("timeout",
                            f"rid={rid}: transfer exceeded {timeout}s")
    imported = []
    try:                                         # phase 2: verify + import
        for s_cache, d_cache, ctrl, payload, digest in staged:
            if payload_checksum(payload) != digest:
                raise TransferError(
                    "corrupt", f"rid={rid}: transfer checksum mismatch")
            try:
                if isinstance(s_cache, PagedCacheBase):
                    d_cache.import_blocks(rid, ctrl["length"], payload)
                else:
                    d_cache.import_blocks(rid, payload)
            except MemoryError as e:
                raise TransferError("oom", f"rid={rid}: {e}") from e
            imported.append(d_cache)
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TransferError(
                    "timeout", f"rid={rid}: transfer exceeded {timeout}s")
    except TransferError:
        for d_cache in imported:                 # roll back partial imports
            d_cache.free(rid)
        raise
    for s_cache, *_ in staged:                   # phase 3: release source
        s_cache.free(rid)
    return moved
