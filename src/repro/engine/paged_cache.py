"""Paged cache management (paper §4.5).

Centralized, paged memory for both the KV cache and the image-token cache
with a *unified* management + transfer interface: the image cache is a
one-layer, single-tensor cache (block size 576 = one LLaVA image), the KV
cache is a multi-layer, two-tensor cache (block size 16).  Fixed-size
recurrent state (SSM/MLA-conv) lives in a per-request StateStore with the
same transfer interface, so migration code is cache-kind-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


class BlockAllocator:
    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self.free = list(range(num_blocks - 1, -1, -1))

    def alloc(self, n: int) -> list:
        if n > len(self.free):
            raise MemoryError(f"cache OOM: need {n}, free {len(self.free)}")
        return [self.free.pop() for _ in range(n)]

    def release(self, blocks: list):
        self.free.extend(blocks)

    @property
    def n_free(self) -> int:
        return len(self.free)


@dataclass
class PagedCacheSpec:
    n_tensors: int       # 2 for KV (k+v), 1 for image tokens
    n_layers: int
    block_size: int      # tokens per block (16 KV / 576 image)
    width: int           # per-token feature width
    num_blocks: int
    dtype: object = np.float32


class PagedCache:
    """Block-granular token cache.  Storage: [T, L, num_blocks, bs, width]."""

    def __init__(self, spec: PagedCacheSpec):
        self.spec = spec
        s = spec
        self.data = np.zeros((s.n_tensors, s.n_layers, s.num_blocks,
                              s.block_size, s.width), s.dtype)
        self.allocator = BlockAllocator(s.num_blocks)
        self.tables: dict[int, list] = {}    # rid -> [block ids]
        self.lengths: dict[int, int] = {}    # rid -> tokens stored

    # ------------------------------------------------------------------
    def _ensure_capacity(self, rid: int, n_tokens: int):
        bs = self.spec.block_size
        table = self.tables.setdefault(rid, [])
        self.lengths.setdefault(rid, 0)
        need_blocks = -(-n_tokens // bs)
        if need_blocks > len(table):
            table.extend(self.allocator.alloc(need_blocks - len(table)))

    def can_fit(self, n_tokens: int) -> bool:
        return -(-n_tokens // self.spec.block_size) <= self.allocator.n_free

    def append(self, rid: int, values: np.ndarray):
        """values: [T(=n_tensors), L, n_new, width] appended at the tail."""
        n_new = values.shape[2]
        start = self.lengths.get(rid, 0)
        self._ensure_capacity(rid, start + n_new)
        bs = self.spec.block_size
        table = self.tables[rid]
        for j in range(n_new):
            pos = start + j
            blk, off = table[pos // bs], pos % bs
            self.data[:, :, blk, off] = values[:, :, j]
        self.lengths[rid] = start + n_new

    def gather(self, rid: int) -> np.ndarray:
        """Contiguous [n_tensors, L, length, width] view-copy."""
        n = self.lengths.get(rid, 0)
        s = self.spec
        out = np.empty((s.n_tensors, s.n_layers, n, s.width), s.dtype)
        bs = s.block_size
        table = self.tables.get(rid, [])
        for b0 in range(0, n, bs):
            blk = table[b0 // bs]
            m = min(bs, n - b0)
            out[:, :, b0:b0 + m] = self.data[:, :, blk, :m]
        return out

    def free(self, rid: int):
        blocks = self.tables.pop(rid, [])
        self.lengths.pop(rid, None)
        self.allocator.release(blocks)

    # ------------------------------------------------------------------
    # migration transfer interface (paper §4.3, unified for KV/image)
    # ------------------------------------------------------------------
    def export_control(self, rid: int) -> dict:
        """Step 1: control info (page table metadata), no bulk data."""
        return {"rid": rid, "length": self.lengths.get(rid, 0),
                "blocks": list(self.tables.get(rid, []))}

    def read_blocks(self, rid: int) -> np.ndarray:
        """Step 3: source-side bulk read of the request's blocks."""
        table = self.tables.get(rid, [])
        return self.data[:, :, table].copy()

    def import_blocks(self, rid: int, length: int, payload: np.ndarray):
        """Step 2+3 target side: allocate pages, then write pulled blocks."""
        n_blocks = payload.shape[2]
        blocks = self.allocator.alloc(n_blocks)
        self.tables[rid] = blocks
        self.lengths[rid] = length
        for i, blk in enumerate(blocks):
            self.data[:, :, blk] = payload[:, :, i]

    def nbytes(self, rid: int) -> int:
        s = self.spec
        return (len(self.tables.get(rid, [])) * s.n_tensors * s.n_layers *
                s.block_size * s.width * self.data.itemsize)


class StateStore:
    """Fixed-size per-request state (SSM state/conv, MLA rope cache, cross-KV)
    with the same export/import surface as PagedCache."""

    def __init__(self):
        self.store: dict[int, dict] = {}

    def put(self, rid: int, tree: dict):
        self.store[rid] = tree

    def get(self, rid: int) -> Optional[dict]:
        return self.store.get(rid)

    def free(self, rid: int):
        self.store.pop(rid, None)

    def export_control(self, rid: int) -> dict:
        return {"rid": rid, "keys": sorted(self.store.get(rid, {}).keys())}

    def read_blocks(self, rid: int) -> dict:
        return self.store.get(rid, {})

    def import_blocks(self, rid: int, payload: dict):
        self.store[rid] = payload

    def nbytes(self, rid: int) -> int:
        tree = self.store.get(rid, {})
        total = 0

        def walk(x):
            nonlocal total
            if isinstance(x, dict):
                for v in x.values():
                    walk(v)
            elif hasattr(x, "nbytes"):
                total += x.nbytes
        walk(tree)
        return total


def migrate_request(rid: int, src, dst) -> int:
    """4-step pull-based migration (paper §4.3) over the unified interface.

    1. source sends control info; 2. target allocates pages and requests the
    blocks; 3. source transfers asynchronously (modeled synchronously here);
    4. target confirms, source releases.  Returns bytes moved.
    """
    moved = 0
    for s_cache, d_cache in zip(src, dst):
        ctrl = s_cache.export_control(rid)                     # step 1
        payload = s_cache.read_blocks(rid)                     # step 3 (pull)
        if isinstance(s_cache, PagedCache):
            moved += s_cache.nbytes(rid)
            d_cache.import_blocks(rid, ctrl["length"], payload)  # step 2+3
        else:
            moved += s_cache.nbytes(rid)
            d_cache.import_blocks(rid, payload)
        s_cache.free(rid)                                      # step 4
    return moved
