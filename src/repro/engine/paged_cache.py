"""Paged cache management (paper §4.5).

Centralized, paged memory for both the KV cache and the image-token cache
with a *unified* management + transfer interface: the image cache is a
one-layer, single-tensor cache (block size 576 = one LLaVA image), the KV
cache is a multi-layer, two-tensor cache (block size 16).  Fixed-size
recurrent state (SSM/MLA-conv) lives in a per-request StateStore with the
same transfer interface, so migration code is cache-kind-agnostic.

Two storage backends share the layout ``[T, L, num_blocks, bs, width]`` and
the full transfer surface:

  PagedCache        host numpy — prefill staging, migration endpoints
  DevicePagedCache  jnp device arrays — the decode hot path reads pages
                    through the Pallas paged-attention kernel and appends
                    via the fused cache-write kernel without ever copying
                    the cache to the host (DESIGN.md §11)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


class BlockAllocator:
    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self.free = list(range(num_blocks - 1, -1, -1))

    def alloc(self, n: int) -> list:
        if n > len(self.free):
            raise MemoryError(f"cache OOM: need {n}, free {len(self.free)}")
        return [self.free.pop() for _ in range(n)]

    def release(self, blocks: list):
        self.free.extend(blocks)

    @property
    def n_free(self) -> int:
        return len(self.free)


@dataclass
class PagedCacheSpec:
    n_tensors: int       # 2 for KV (k+v), 1 for image tokens
    n_layers: int
    block_size: int      # tokens per block (16 KV / 576 image)
    width: int           # per-token feature width
    num_blocks: int
    dtype: object = np.float32


class PagedCacheBase:
    """Shared block-table bookkeeping for both storage backends."""

    def __init__(self, spec: PagedCacheSpec):
        self.spec = spec
        self.allocator = BlockAllocator(spec.num_blocks)
        self.tables: dict[int, list] = {}    # rid -> [block ids]
        self.lengths: dict[int, int] = {}    # rid -> tokens stored

    def _ensure_capacity(self, rid: int, n_tokens: int):
        bs = self.spec.block_size
        table = self.tables.setdefault(rid, [])
        self.lengths.setdefault(rid, 0)
        need_blocks = -(-n_tokens // bs)
        if need_blocks > len(table):
            table.extend(self.allocator.alloc(need_blocks - len(table)))

    def can_fit(self, n_tokens: int) -> bool:
        return -(-n_tokens // self.spec.block_size) <= self.allocator.n_free

    def free(self, rid: int):
        blocks = self.tables.pop(rid, [])
        self.lengths.pop(rid, None)
        self.allocator.release(blocks)

    def _slot_arrays(self, rid: int, start: int, n: int):
        """(block ids, in-block offsets) for token positions [start, start+n)."""
        pos = np.arange(start, start + n)
        bs = self.spec.block_size
        table = np.asarray(self.tables.get(rid, []), np.int64)
        return table[pos // bs], pos % bs

    def row_slots(self, rid: int, start: int, n: int) -> np.ndarray:
        """Within-plane row slots (``block * bs + offset``) for token
        positions [start, start+n) — the device-side gather/scatter
        addresses of those tokens."""
        blks, offs = self._slot_arrays(rid, start, n)
        return (blks * self.spec.block_size + offs).astype(np.int32)

    # ------------------------------------------------------------------
    # migration transfer interface (paper §4.3, unified for KV/image)
    # ------------------------------------------------------------------
    def export_control(self, rid: int) -> dict:
        """Step 1: control info (page table metadata), no bulk data."""
        return {"rid": rid, "length": self.lengths.get(rid, 0),
                "blocks": list(self.tables.get(rid, []))}

    def nbytes(self, rid: int) -> int:
        s = self.spec
        return (len(self.tables.get(rid, [])) * s.n_tensors * s.n_layers *
                s.block_size * s.width * np.dtype(s.dtype).itemsize)


class PagedCache(PagedCacheBase):
    """Host (numpy) paged cache.  Storage: [T, L, num_blocks, bs, width]."""

    def __init__(self, spec: PagedCacheSpec):
        super().__init__(spec)
        s = spec
        self.data = np.zeros((s.n_tensors, s.n_layers, s.num_blocks,
                              s.block_size, s.width), s.dtype)

    def append(self, rid: int, values: np.ndarray):
        """values: [T(=n_tensors), L, n_new, width] appended at the tail."""
        n_new = values.shape[2]
        start = self.lengths.get(rid, 0)
        self._ensure_capacity(rid, start + n_new)
        blks, offs = self._slot_arrays(rid, start, n_new)
        self.data[:, :, blks, offs] = np.asarray(values)
        self.lengths[rid] = start + n_new

    def gather(self, rid: int) -> np.ndarray:
        """Contiguous [n_tensors, L, length, width] view-copy."""
        n = self.lengths.get(rid, 0)
        blks, offs = self._slot_arrays(rid, 0, n)
        return self.data[:, :, blks, offs]

    def read_blocks(self, rid: int) -> np.ndarray:
        """Step 3: source-side bulk read of the request's blocks."""
        table = self.tables.get(rid, [])
        return self.data[:, :, table].copy()

    def import_blocks(self, rid: int, length: int, payload: np.ndarray):
        """Step 2+3 target side: allocate pages, then write pulled blocks."""
        n_blocks = payload.shape[2]
        blocks = self.allocator.alloc(n_blocks)
        self.tables[rid] = blocks
        self.lengths[rid] = length
        self.data[:, :, blocks] = np.asarray(payload)


_DEVICE_APPEND = None


def _device_append(data, rows, slot_vec):
    """Jitted pool-donating append: scatter ``rows`` at ``slot_vec`` into the
    flattened [T*L*NB, bs, w] view of ``data`` and return it, in place."""
    global _DEVICE_APPEND
    if _DEVICE_APPEND is None:
        import jax
        from repro.kernels.cache_write.ops import cache_write

        def impl(data, rows, slot_vec):
            T, L, NB, bs, w = data.shape
            flat = data.reshape(T * L * NB, bs, w)
            flat = cache_write(flat, rows, slot_vec, use_kernel=False)
            return flat.reshape(T, L, NB, bs, w)

        _DEVICE_APPEND = jax.jit(impl, donate_argnums=(0,))
    return _DEVICE_APPEND(data, rows, slot_vec)


class DevicePagedCache(PagedCacheBase):
    """Device-resident paged cache: block storage lives as one jnp array of
    the same ``[T, L, num_blocks(+1), bs, width]`` layout, so the decode hot
    path can hand pages + block tables straight to the Pallas paged-attention
    / cache-write kernels without any host round-trip.

    One extra *scratch* block (physical index ``num_blocks``) absorbs the
    writes and reads of padded batch lanes introduced by batch-size
    bucketing; the allocator never hands it out.
    """

    def __init__(self, spec: PagedCacheSpec):
        super().__init__(spec)
        import jax.numpy as jnp  # deferred: host-only tools never pay for jax
        self._jnp = jnp
        s = spec
        self.data = jnp.zeros((s.n_tensors, s.n_layers, s.num_blocks + 1,
                               s.block_size, s.width), s.dtype)

    @property
    def scratch_block(self) -> int:
        return self.spec.num_blocks

    # -- host-interop append/gather (prefill staging, migration) ----------
    def append(self, rid: int, values):
        """values: [T, L, n_new, width] (np or jnp) appended at the tail.

        Goes through the buffer-donating ``cache_write`` op (ref backend)
        under a jit that owns the pool exclusively: one fused in-place
        scatter instead of copying the whole pool.  (The reshape must stay
        inside the jit — an eager reshape would create a second buffer
        handle and defeat donation.)
        """
        jnp = self._jnp
        n_new = values.shape[2]
        start = self.lengths.get(rid, 0)
        self._ensure_capacity(rid, start + n_new)
        blks, offs = self._slot_arrays(rid, start, n_new)
        s = self.spec
        T, L, NB = s.n_tensors, s.n_layers, s.num_blocks + 1
        bs = s.block_size
        plane = (np.arange(T)[:, None] * L + np.arange(L)[None, :]) * (NB * bs)
        slot_vec = (plane[:, :, None] + (blks * bs + offs)[None, None, :])
        rows = jnp.asarray(values, self.data.dtype).reshape(
            T * L * n_new, s.width)
        self.data = _device_append(self.data, rows,
                                   jnp.asarray(slot_vec.reshape(-1),
                                               jnp.int32))
        self.lengths[rid] = start + n_new

    def gather(self, rid: int):
        """Contiguous [n_tensors, L, length, width] *device* array."""
        n = self.lengths.get(rid, 0)
        blks, offs = self._slot_arrays(rid, 0, n)
        return self.data[:, :, blks, offs]

    def read_blocks(self, rid: int):
        table = np.asarray(self.tables.get(rid, []), np.int64)
        return self.data[:, :, table]

    def import_blocks(self, rid: int, length: int, payload):
        n_blocks = payload.shape[2]
        blocks = self.allocator.alloc(n_blocks)
        self.tables[rid] = blocks
        self.lengths[rid] = length
        self.data = self.data.at[:, :, np.asarray(blocks, np.int64)].set(
            self._jnp.asarray(payload, self.data.dtype))

    # -- decode hot path ---------------------------------------------------
    def prepare_decode(self, rids: list, batch_pad: int, pages_pad: int):
        """Per-step control tensors for the jitted paged decode.

        Allocates one-token headroom per request, then returns host int32
        arrays (tiny; the bulk cache never moves):

          tables [batch_pad, pages_pad]  block table, scratch-padded
          slots  [batch_pad]             within-plane row slot (block*bs+off)
                                         of the token being appended
        Padded lanes point at the scratch block so their writes land off to
        the side and their (discarded) reads stay in bounds.
        """
        bs = self.spec.block_size
        scratch = self.scratch_block
        tables = np.full((batch_pad, pages_pad), scratch, np.int32)
        slots = np.full((batch_pad,), scratch * bs, np.int32)
        for b, rid in enumerate(rids):
            n = self.lengths.get(rid, 0)
            self._ensure_capacity(rid, n + 1)
            table = self.tables[rid]
            tables[b, :len(table)] = table
            slots[b] = table[n // bs] * bs + n % bs
        return tables, slots

    def commit_decode(self, rids: list):
        """Account the one token per request that the kernel just wrote."""
        for rid in rids:
            self.lengths[rid] = self.lengths.get(rid, 0) + 1

    # -- batched chunked prefill -------------------------------------------
    def prepare_prefill(self, rids: list, n_new: list, batch_pad: int,
                        chunk_pad: int, pages_pad: int):
        """Per-chunk control tensors for the jitted batched prefill.

        Allocates ``n_new[i]``-token headroom per request, then returns
        host int32 arrays (tiny; the bulk cache never moves):

          tables [batch_pad, pages_pad]   block table, scratch-padded
          slots  [batch_pad, chunk_pad]   within-plane row slot of each
                                          chunk token being appended
        Padded lanes and padded chunk positions point at the scratch block
        so their writes land off to the side and their (discarded) reads
        stay in bounds.
        """
        bs = self.spec.block_size
        scratch = self.scratch_block
        tables = np.full((batch_pad, pages_pad), scratch, np.int32)
        slots = np.full((batch_pad, chunk_pad), scratch * bs, np.int32)
        for b, (rid, n) in enumerate(zip(rids, n_new)):
            start = self.lengths.get(rid, 0)
            self._ensure_capacity(rid, start + n)
            table = self.tables[rid]
            tables[b, :len(table)] = table
            slots[b, :n] = self.row_slots(rid, start, n)
        return tables, slots

    def commit_prefill(self, rids: list, n_new: list):
        """Account the chunk tokens the kernel just wrote per request."""
        for rid, n in zip(rids, n_new):
            self.lengths[rid] = self.lengths.get(rid, 0) + n


class StateStore:
    """Fixed-size per-request state (SSM state/conv, MLA rope cache, cross-KV)
    with the same export/import surface as PagedCache."""

    def __init__(self):
        self.store: dict[int, dict] = {}

    def put(self, rid: int, tree: dict):
        self.store[rid] = tree

    def get(self, rid: int) -> Optional[dict]:
        return self.store.get(rid)

    def free(self, rid: int):
        self.store.pop(rid, None)

    def export_control(self, rid: int) -> dict:
        return {"rid": rid, "keys": sorted(self.store.get(rid, {}).keys())}

    def read_blocks(self, rid: int) -> dict:
        return self.store.get(rid, {})

    def import_blocks(self, rid: int, payload: dict):
        self.store[rid] = payload

    def nbytes(self, rid: int) -> int:
        tree = self.store.get(rid, {})
        total = 0

        def walk(x):
            nonlocal total
            if isinstance(x, dict):
                for v in x.values():
                    walk(v)
            elif hasattr(x, "nbytes"):
                total += x.nbytes
        walk(tree)
        return total


def migrate_request(rid: int, src, dst) -> int:
    """4-step pull-based migration (paper §4.3) over the unified interface.

    1. source sends control info; 2. target allocates pages and requests the
    blocks; 3. source transfers asynchronously (modeled synchronously here);
    4. target confirms, source releases.  Returns bytes moved.
    """
    moved = 0
    for s_cache, d_cache in zip(src, dst):
        ctrl = s_cache.export_control(rid)                     # step 1
        payload = s_cache.read_blocks(rid)                     # step 3 (pull)
        if isinstance(s_cache, PagedCacheBase):
            moved += s_cache.nbytes(rid)
            d_cache.import_blocks(rid, ctrl["length"], payload)  # step 2+3
        else:
            moved += s_cache.nbytes(rid)
            d_cache.import_blocks(rid, payload)
        s_cache.free(rid)                                      # step 4
    return moved
