"""ModelRunner: real-JAX stage execution over paged caches.

Executes the three HydraInfer stages on actual model weights:

  encode         : modality frontend -> image-token cache (paged, block 576)
  prefill_chunks : ONE batched chunked-prefill step for every request's
                   chunk this iteration (paged KV; DESIGN.md §12)
  decode         : batched one-token step over heterogeneous contexts
  joint_step     : encode + decode fused into ONE jitted computation — the
                   TPU-native analogue of the paper's two CUDA streams

Decode and prefill each have two paths (DESIGN.md §11/§12):

  device-resident paged (default in the engine): block storage stays on
  device as jnp arrays; the jitted step reads pages + block tables through
  the Pallas paged-attention kernel (compiled on TPU, interpret mode on
  CPU) and appends the new token — or the whole prefill chunk — in place
  via the fused cache-write kernel.  Only tiny control tensors (block
  tables, lengths, slots) and the logits cross the host boundary each
  step.  Batch size, chunk length, and page count are bucketed to powers
  of two so the steps compile O(log) distinct shapes.

  dense gather (``device=False`` caches): the seed fallback — per-request
  host gather, padded concat, full cache scatter / numpy chunk round-trip.
  Kept for migration endpoints and as the benchmark baseline.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN_MLP, ATTN_MOE, MLA_MLP, MLA_MOE, MAMBA1,
                                MAMBA2, SHARED_ATTN, ModelConfig)
from repro.engine.paged_cache import (DevicePagedCache, PagedCache,
                                      PagedCacheSpec, StateStore,
                                      migrate_request)
from repro.models import mamba
from repro.models import model as M

KV_BLOCK = 16        # paper §5.1
IMG_BLOCK = 576      # paper §5.1 (one LLaVA-1.5 image)


def bucket_pow2(n: int) -> int:
    """Smallest power of two >= n (jit shape bucketing)."""
    return 1 << max(0, n - 1).bit_length()


def default_attn_impl() -> str:
    """Paged-kernel backend: compiled on TPU, interpret mode elsewhere.
    Override with REPRO_PAGED_IMPL=kernel|interpret|ref."""
    env = os.environ.get("REPRO_PAGED_IMPL")
    if env:
        return env
    return "kernel" if jax.default_backend() == "tpu" else "interpret"


def _seq_layers(cfg: ModelConfig):
    """(attn_layer_ids, mla_layer_ids) — layers with seq-like paged caches."""
    attn, mla = [], []
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind in (MLA_MLP, MLA_MOE):
            mla.append(i)
        elif kind in (ATTN_MLP, ATTN_MOE, SHARED_ATTN):
            attn.append(i)
    return attn, mla


class RunnerCaches:
    """Per-instance cache pool: paged KV + paged image cache + state store,
    all sharing the unified transfer interface (paper §4.5)."""

    def __init__(self, cfg: ModelConfig, *, kv_blocks: int = 512,
                 img_blocks: int = 16, dtype=np.float32,
                 device: bool = False, sharing: bool = False):
        self.cfg = cfg
        self.device = device
        cache_cls = DevicePagedCache if device else PagedCache
        self.attn_layers, self.mla_layers = _seq_layers(cfg)
        # Prefix sharing of the seq caches is unsound for architectures
        # with recurrent (SSM) layers: the mamba state at a prefix boundary
        # is not paged/snapshotted, so an adopted KV prefix would pair with
        # a zero recurrent state.  Gate seq-cache sharing off there; the
        # image cache (pure content, position-free) still shares.
        kinds = cfg.layer_kinds()
        self.has_recurrent = any(k in (MAMBA1, MAMBA2) for k in kinds)
        self.sharing = sharing
        share_seq = sharing and not self.has_recurrent
        stores = []
        self.kv = self.mla = self.img = None
        if self.attn_layers:
            self.kv = cache_cls(PagedCacheSpec(
                n_tensors=2, n_layers=len(self.attn_layers),
                block_size=KV_BLOCK, width=cfg.num_kv_heads * cfg.head_dim,
                num_blocks=kv_blocks, dtype=dtype), sharing=share_seq)
            stores.append(self.kv)
        if self.mla_layers:
            self.mla = cache_cls(PagedCacheSpec(
                n_tensors=1, n_layers=len(self.mla_layers),
                block_size=KV_BLOCK,
                width=cfg.kv_lora_rank + cfg.qk_rope_head_dim,
                num_blocks=kv_blocks, dtype=dtype), sharing=share_seq)
            stores.append(self.mla)
        if cfg.frontend != "none":
            # one image per block so a repeated image shares exactly its
            # own pages (media_tokens when set, the LLaVA default otherwise)
            self.img = cache_cls(PagedCacheSpec(
                n_tensors=1, n_layers=1,
                block_size=cfg.media_tokens or IMG_BLOCK,
                width=cfg.d_model, num_blocks=img_blocks, dtype=dtype),
                sharing=sharing)
            stores.append(self.img)
        self.states = StateStore()
        stores.append(self.states)
        self.stores = stores

    def release(self, rid: int):
        """THE release path for every retire/abort/migrate-source site: with
        sharing enabled this drops *references* — a block survives while any
        other request's table still points at it (ISSUE 6 satellite: the
        PR-4 leak class came from per-path bookkeeping divergence)."""
        for s in self.stores:
            s.free(rid)

    # legacy alias: callers predating the sharing work said "free"
    free = release

    def kv_tokens_free(self) -> int:
        pools = [c for c in (self.kv, self.mla) if c is not None]
        if not pools:
            return 1 << 30  # SSM-only: no token-proportional cache
        return min(c.available_blocks * c.spec.block_size for c in pools)

    def kv_tokens_total(self) -> int:
        """Whole-pool KV capacity in tokens: the admission check's
        can-this-request-EVER-fit bound (DESIGN.md §15)."""
        pools = [c for c in (self.kv, self.mla) if c is not None]
        if not pools:
            return 1 << 30
        return min(c.spec.num_blocks * c.spec.block_size for c in pools)

    def live_rids(self) -> set:
        """Every rid holding any state on this instance's stores — the set
        an instance quarantine must release (DESIGN.md §15)."""
        rids: set = set()
        for s in self.stores:
            if isinstance(s, StateStore):
                rids.update(s.store.keys())
            else:
                rids.update(s.tables.keys())
        return rids


def migrate(rid: int, src: RunnerCaches, dst: RunnerCaches, *,
            fault=None, timeout=None) -> int:
    return migrate_request(rid, src.stores, dst.stores, fault=fault,
                           timeout=timeout)


class ModelRunner:
    def __init__(self, cfg: ModelConfig, params, caches: RunnerCaches, *,
                 attn_impl: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.caches = caches
        self.attn_impl = attn_impl or default_attn_impl()
        self._decode_jit = jax.jit(functools.partial(M.decode_step, cfg))
        self._encode_jit = jax.jit(functools.partial(M.encode_media, cfg))
        self._joint_jit = jax.jit(self._joint_fn)
        # device-paged decode: the cache buffers are donated so the
        # cache-write lands in place — without this every step would copy
        # the whole pool just to insert one row per request.  (Backends
        # without donation support fall back to a copy with a warning.)
        self._paged_jit = jax.jit(
            functools.partial(M.decode_step_paged, cfg,
                              attn_impl=self.attn_impl),
            donate_argnums=(1,))
        self._joint_paged_jit = jax.jit(self._joint_paged_fn,
                                        donate_argnums=(2,))
        # batched chunked prefill over the same device-resident caches
        # (DESIGN.md §12): the page pools are donated for the same reason
        self._prefill_jit = jax.jit(
            functools.partial(M.prefill_chunk_paged, cfg,
                              attn_impl=self.attn_impl),
            donate_argnums=(1,))
        # standalone sampler for the dense fallback paths (the paged paths
        # fuse sampling into the step jit via ctl["sample"])
        self._sample_jit = jax.jit(M.sample_from_logits)
        # all-greedy fast path: plain on-device argmax over the no-sample
        # trace's logits — skips the top-k/top-p sorts entirely while still
        # sending only [B] ints to the host (two dispatches, zero copies)
        self._argmax_jit = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))

    # ------------------------------------------------------------------
    # sampling control prep
    # ------------------------------------------------------------------
    @staticmethod
    def _all_greedy(sample, idxs=None) -> bool:
        if sample is None:
            return False
        t = np.asarray(sample["temp"])
        return not np.any((t if idxs is None else t[idxs]) > 0)

    @staticmethod
    def _sample_ctl(sample, B_pad: int, idxs=None):
        """Pad/select host sample arrays (see ``M.sample_from_logits``) into
        the jit's control subtree.  Padded lanes get temp=0 (greedy over
        garbage logits, discarded on the host)."""
        if sample is None:
            return None
        out = {}
        for name, dt in (("temp", np.float32), ("top_k", np.int32),
                         ("top_p", np.float32), ("seed", np.uint32),
                         ("step", np.int32)):
            v = np.asarray(sample[name], dt)
            if idxs is not None:
                v = v[idxs]
            pad = B_pad - v.shape[0]
            if pad:
                v = np.concatenate([v, np.zeros(pad, dt)])
            out[name] = jnp.asarray(v)
        return out

    # ------------------------------------------------------------------
    # encode stage
    # ------------------------------------------------------------------
    def encode(self, items):
        """items: [(rid, media [n_media, d_model])] -> image cache entries.

        One item per media element, so a multi-image request contributes
        several items (same rid) that batch alongside everyone else's.
        Mixed media shapes batch per shape group, but the results commit in
        the original item order, so a request's images always land in its
        image cache in submission order.
        """
        if not items:
            return
        groups: dict[tuple, list] = {}          # shape -> item indices
        for i, (_, m) in enumerate(items):
            groups.setdefault(m.shape, []).append(i)
        embs: list = [None] * len(items)
        for idxs in groups.values():
            grp = [items[i] for i in idxs]
            emb = self._encode_jit(self.params, self._media_batch(grp))
            if not self.caches.device:  # host caches: one batched transfer
                emb = np.asarray(emb)
            for i, e in zip(idxs, emb):
                embs[i] = e
        self._store_encoded(items, embs)

    def _media_batch(self, items):
        """Stack media, padding the batch to a power of two (shape bucket)."""
        media = jnp.stack([m for _, m in items])
        pad = bucket_pow2(media.shape[0]) - media.shape[0]
        if pad:
            media = jnp.concatenate(
                [media, jnp.zeros((pad,) + media.shape[1:], media.dtype)], 0)
        return media

    def _store_encoded(self, items, emb):
        for (rid, _), e in zip(items, emb):
            if self.cfg.cross_attention:
                st = self.caches.states.get(rid) or {}
                if "enc_out" in st:  # later media of the same request
                    e = jnp.concatenate([jnp.asarray(st["enc_out"]), e], 0)
                st["enc_out"] = e
                self.caches.states.put(rid, st)
            else:
                self.caches.img.append(rid, e[None, None])  # [1, 1, T, d]

    # ------------------------------------------------------------------
    # prefill (chunked)
    # ------------------------------------------------------------------
    def _gather_prior(self, rid: int, dtype=jnp.float32):
        cfg = self.cfg
        ents = [dict() for _ in range(cfg.num_layers)]
        if self.caches.kv is not None:
            kv = self.caches.kv.gather(rid)        # [2, L_attn, n, w]
            for j, li in enumerate(self.caches.attn_layers):
                ents[li] = {"k": jnp.asarray(kv[0, j])[None],
                            "v": jnp.asarray(kv[1, j])[None]}
        if self.caches.mla is not None:
            lat = self.caches.mla.gather(rid)      # [1, L_mla, n, R+rope]
            R = cfg.kv_lora_rank
            for j, li in enumerate(self.caches.mla_layers):
                ents[li] = {"ckv": jnp.asarray(lat[0, j, :, :R])[None],
                            "krope": jnp.asarray(lat[0, j, :, R:])[None]}
        st = self.caches.states.get(rid) or {}
        for i, kind in enumerate(cfg.layer_kinds()):
            if kind in (MAMBA1, MAMBA2):
                s = st.get(f"mamba{i}")  # arrays stored with batch dim 1
                ents[i] = {"state": None if s is None else jnp.asarray(s["state"]),
                           "conv": None if s is None else jnp.asarray(s["conv"])}
            if cfg.cross_attention and f"xk{i}" in st:
                ents[i]["xk"] = jnp.asarray(st[f"xk{i}"])
                ents[i]["xv"] = jnp.asarray(st[f"xv{i}"])
        return {"layers": ents}

    def _append_entries(self, rid: int, entries):
        cfg = self.cfg
        if self.caches.kv is not None:
            ks, vs = [], []
            for li in self.caches.attn_layers:
                e = entries["layers"][li]
                ks.append(np.asarray(e["k"][0]))
                vs.append(np.asarray(e["v"][0]))
            self.caches.kv.append(rid, np.stack([np.stack(ks), np.stack(vs)]))
        if self.caches.mla is not None:
            lats = []
            for li in self.caches.mla_layers:
                e = entries["layers"][li]
                lats.append(np.concatenate([np.asarray(e["ckv"][0]),
                                            np.asarray(e["krope"][0])], -1))
            self.caches.mla.append(rid, np.stack(lats)[None])
        st = self.caches.states.get(rid) or {}
        for i, kind in enumerate(cfg.layer_kinds()):
            e = entries["layers"][i]
            if kind in (MAMBA1, MAMBA2):
                st[f"mamba{i}"] = {"state": np.asarray(e["state"]),
                                   "conv": np.asarray(e["conv"])}
            if cfg.cross_attention and "xk" in e:
                st[f"xk{i}"] = np.asarray(e["xk"])
                st[f"xv{i}"] = np.asarray(e["xv"])
        self.caches.states.put(rid, st)

    def prefill_chunk(self, rid: int, tokens: Optional[np.ndarray], *,
                      use_media: bool = False):
        """Run one chunk; returns last-token logits [V] (np).  Device caches
        go through the batched paged path (B=1); host caches run the dense
        gather/concat fallback."""
        if self.caches.device:
            return self.prefill_chunks([(rid, tokens, use_media)])[0]
        return self._prefill_chunk_dense(rid, tokens, use_media=use_media)

    def _prefill_chunk_dense(self, rid: int, tokens: Optional[np.ndarray], *,
                             use_media: bool = False):
        cfg = self.cfg
        prior = self._gather_prior(rid)
        offset = self._ctx_len(rid)
        media_emb = None
        enc_out = None
        if use_media and self.caches.img is not None:
            media_emb = jnp.asarray(self.caches.img.gather(rid)[0, 0])[None]
        st = self.caches.states.get(rid) or {}
        if cfg.cross_attention and "enc_out" in st:
            enc_out = jnp.asarray(st["enc_out"])[None]
        tok = None if tokens is None else jnp.asarray(tokens)[None]
        logits, entries = M.prefill_chunk(cfg, self.params, tok, prior,
                                          offset, enc_out=enc_out,
                                          media_emb=media_emb)
        self._append_entries(rid, entries)
        n_new = (0 if tokens is None else len(tokens)) + \
            (media_emb.shape[1] if media_emb is not None else 0)
        st = self.caches.states.get(rid) or {}
        st["ctx_len"] = offset + n_new
        self.caches.states.put(rid, st)
        return np.asarray(logits[0])

    def _ctx_len(self, rid: int) -> int:
        if self.caches.kv is not None:
            return self.caches.kv.lengths.get(rid, 0)
        if self.caches.mla is not None:
            return self.caches.mla.lengths.get(rid, 0)
        st = self.caches.states.get(rid) or {}
        return int(st.get("ctx_len", 0))

    # ------------------------------------------------------------------
    # prefill (batched, device-resident paged path, DESIGN.md §12)
    # ------------------------------------------------------------------
    def prefill_chunks(self, items, sample=None):
        """One prefill chunk for a batch of requests.  items: [(rid,
        tokens | None, use_media)].  Returns last-token logits
        [len(items), V] (np) in input order — or, when ``sample`` carries
        per-item sampling controls, the sampled next-token ids
        [len(items)] (np int32; only meaningful for items whose prefill
        completes this chunk).

        Device caches run ONE jitted ``prefill_chunk_paged`` call per pow2
        chunk-length bucket (so a whole-image media chunk doesn't pad every
        short text chunk up to its length), batch-padded to a power of two;
        host caches fall back to the per-request dense path.
        """
        if not self.caches.device:
            lg = np.stack([self._prefill_chunk_dense(rid, toks, use_media=um)
                           for rid, toks, um in items])
            if sample is None:
                return lg
            if self._all_greedy(sample):
                return np.argmax(lg, axis=-1).astype(np.int32)
            return np.asarray(self._sample_jit(
                jnp.asarray(lg), self._sample_ctl(sample, len(items))))
        out = np.zeros((len(items),) if sample is not None
                       else (len(items), self.cfg.vocab_size),
                       np.int32 if sample is not None else np.float32)
        groups: dict[int, list] = {}
        for idx, (rid, toks, um) in enumerate(items):
            n = (0 if toks is None else len(toks)) + \
                (self.caches.img.lengths.get(rid, 0) if um else 0)
            groups.setdefault(bucket_pow2(max(n, 1)), []).append(
                (idx, rid, toks, um, n))
        for C_pad, grp in sorted(groups.items()):
            res = self._prefill_group(grp, C_pad, sample=sample)
            for (idx, *_), lg in zip(grp, res):
                out[idx] = lg
        return out

    def _prefill_group(self, grp, C_pad: int, sample=None):
        """Run one equal-bucket group: [(idx, rid, tokens, use_media,
        n_new)] -> last-token logits [len(grp), V] (np), or sampled token
        ids [len(grp)] when ``sample`` is given (fused into the jit)."""
        cfg = self.cfg
        B = len(grp)
        B_pad = bucket_pow2(B)
        rids = [g[1] for g in grp]
        n_new = [g[4] for g in grp]
        ctx = [self._ctx_len(r) for r in rids]
        tokens = np.zeros((B_pad, C_pad), np.int32)
        mask = np.zeros((B_pad, C_pad), bool)
        img_slots = None
        for b, (_, rid, toks, um, n) in enumerate(grp):
            off = 0
            if um:
                m = self.caches.img.lengths.get(rid, 0)
                if img_slots is None:
                    img_slots = np.full((B_pad, C_pad), -1, np.int32)
                img_slots[b, :m] = self.caches.img.row_slots(rid, 0, m)
                off = m
            if toks is not None:
                tokens[b, off:off + len(toks)] = toks
            mask[b, :n] = True
        last = np.zeros(B_pad, np.int32)
        last[:B] = np.maximum(np.asarray(n_new, np.int32) - 1, 0)
        lens_arr = np.zeros(B_pad, np.int32)
        lens_arr[:B] = ctx
        data, ctl = {}, {}
        for name, cache in (("kv", self.caches.kv), ("mla", self.caches.mla)):
            if cache is None:
                continue
            bs = cache.spec.block_size
            pages = max(-(-(c + n) // bs) for c, n in zip(ctx, n_new))
            tables, slots = cache.prepare_prefill(rids, n_new, B_pad, C_pad,
                                                  bucket_pow2(pages))
            data[name] = cache.data
            ctl[name] = {"tables": jnp.asarray(tables),
                         "slots": jnp.asarray(slots)}
        if img_slots is not None:
            # media positions read the device image cache in the jitted
            # step; the pool rides along read-only (not donated)
            ctl["img"] = {"slots": jnp.asarray(img_slots),
                          "pages": self.caches.img.data}
        ctl["mask"] = jnp.asarray(mask)
        ctl["last"] = jnp.asarray(last)
        idxs = np.asarray([g[0] for g in grp])
        greedy = self._all_greedy(sample, idxs)
        if sample is not None and not greedy:
            ctl["sample"] = self._sample_ctl(sample, B_pad, idxs=idxs)
        state = self._prefill_state(rids, B_pad)
        logits, new_paged, new_state = self._prefill_jit(
            self.params, data, ctl, state, jnp.asarray(lens_arr),
            jnp.asarray(tokens))
        if greedy:
            logits = self._argmax_jit(logits)
        for name, cache in (("kv", self.caches.kv), ("mla", self.caches.mla)):
            if name in new_paged:
                cache.data = new_paged[name]
                cache.commit_prefill(rids, n_new)
        for b, (_, rid, toks, um, n) in enumerate(grp):
            st = self.caches.states.get(rid) or {}
            for i, kind in enumerate(cfg.layer_kinds()):
                e = new_state["layers"][i]
                if kind in (MAMBA1, MAMBA2):
                    st[f"mamba{i}"] = {"state": e["state"][b:b + 1],
                                       "conv": e["conv"][b:b + 1]}
                elif cfg.cross_attention and "xk" in e:
                    st[f"xk{i}"] = e["xk"][b:b + 1]
                    st[f"xv{i}"] = e["xv"][b:b + 1]
            st["ctx_len"] = ctx[b] + n
            self.caches.states.put(rid, st)
        return np.asarray(logits[:B])

    def _prefill_state(self, rids, B_pad: int):
        """Batch the small non-paged per-request prefill state: mamba
        state/conv (zeros for first chunks) and the encoder output for
        cross-attention archs.  Padded lanes get zeros."""
        cfg = self.cfg
        pad = B_pad - len(rids)

        def stack(arrs):
            a = jnp.concatenate([jnp.asarray(x) for x in arrs], 0)
            if pad:
                a = jnp.concatenate(
                    [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
            return a

        sts = [self.caches.states.get(r) or {} for r in rids]
        out = []
        for i, kind in enumerate(cfg.layer_kinds()):
            ent = {}
            if kind in (MAMBA1, MAMBA2):
                shapes = (mamba.mamba1_cache_shape(cfg, 1) if kind == MAMBA1
                          else mamba.mamba2_cache_shape(cfg, 1))
                per = [st.get(f"mamba{i}") for st in sts]
                ent["state"] = stack(
                    [np.zeros(shapes["state"], np.float32) if e is None
                     else e["state"] for e in per])
                ent["conv"] = stack(
                    [np.zeros(shapes["conv"], np.float32) if e is None
                     else e["conv"] for e in per])
            out.append(ent)
        tree = {"layers": out}
        if cfg.cross_attention:
            tree["enc_out"] = stack([jnp.asarray(st["enc_out"])[None]
                                     for st in sts])
        return tree

    # ------------------------------------------------------------------
    # decode (batched, heterogeneous contexts)
    # ------------------------------------------------------------------
    def _batched_cache(self, rids):
        cfg = self.cfg
        lens = [self._ctx_len(r) for r in rids]
        # SSM-only archs track context only in states
        S_max = max(lens) + 1 if lens else 1
        B = len(rids)
        priors = [self._gather_prior(r) for r in rids]
        ents_out = []
        for i, kind in enumerate(cfg.layer_kinds()):
            ent = {}
            per = [p["layers"][i] for p in priors]
            if kind in (MAMBA1, MAMBA2):
                ent["state"] = jnp.concatenate([e["state"] for e in per], 0)
                ent["conv"] = jnp.concatenate([e["conv"] for e in per], 0)
            else:
                for name in per[0]:
                    if name in ("xk", "xv"):
                        ent[name] = jnp.concatenate([e[name] for e in per], 0)
                        continue
                    arrs = []
                    for e, L in zip(per, lens):
                        a = e[name]
                        pad = S_max - a.shape[1]
                        arrs.append(jnp.pad(a, ((0, 0), (0, pad), (0, 0))))
                    ent[name] = jnp.concatenate(arrs, 0)
            ents_out.append(ent)
        return {"layers": ents_out}, jnp.asarray(lens, jnp.int32)

    def decode(self, rids, tokens: np.ndarray, sample=None):
        """One decode step for a batch.  tokens: [B].  Returns logits [B, V],
        or sampled next-token ids [B] (np int32) when ``sample`` carries
        per-request sampling controls (see ``M.sample_from_logits``)."""
        if self.caches.device:
            return self._decode_paged(rids, tokens, sample)
        cfg = self.cfg
        cache, lens = self._batched_cache(rids)
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        logits, new_cache = self._decode_jit(self.params, cache, lens, tok)
        self._scatter_decoded(rids, new_cache, lens)
        if sample is not None:
            if self._all_greedy(sample):
                return np.asarray(self._argmax_jit(logits))
            return np.asarray(self._sample_jit(
                logits, self._sample_ctl(sample, len(rids))))
        return np.asarray(logits)

    # ------------------------------------------------------------------
    # decode (device-resident paged path, DESIGN.md §11)
    # ------------------------------------------------------------------
    def _prepare_paged(self, rids):
        """Host-side per-step control prep: one-token block headroom, padded
        block tables / slot mappings / lengths.  All tiny int32 arrays — the
        bulk cache never crosses the host boundary."""
        B = len(rids)
        B_pad = bucket_pow2(B)
        lens = [self._ctx_len(r) for r in rids]
        lens_arr = np.zeros(B_pad, np.int32)
        lens_arr[:B] = lens
        data, ctl = {}, {}
        for name, cache in (("kv", self.caches.kv), ("mla", self.caches.mla)):
            if cache is None:
                continue
            bs = cache.spec.block_size
            pages = max(-(-(n + 1) // bs) for n in lens)
            tables, slots = cache.prepare_decode(rids, B_pad,
                                                 bucket_pow2(pages))
            data[name] = cache.data
            ctl[name] = {"tables": jnp.asarray(tables),
                         "slots": jnp.asarray(slots)}
        state = self._batched_state(rids, B_pad)
        return data, ctl, state, jnp.asarray(lens_arr), lens

    def _batched_state(self, rids, B_pad):
        """Batch the small non-paged per-request state (mamba state/conv,
        whisper cross xk/xv); padded lanes get zeros."""
        cfg = self.cfg
        pad = B_pad - len(rids)

        def stack(arrs):
            a = jnp.concatenate([jnp.asarray(x) for x in arrs], 0)
            if pad:
                a = jnp.concatenate(
                    [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
            return a

        sts = [self.caches.states.get(r) or {} for r in rids]
        out = []
        for i, kind in enumerate(cfg.layer_kinds()):
            ent = {}
            if kind in (MAMBA1, MAMBA2):
                per = [st[f"mamba{i}"] for st in sts]
                ent["state"] = stack([e["state"] for e in per])
                ent["conv"] = stack([e["conv"] for e in per])
            elif cfg.cross_attention and any(f"xk{i}" in st for st in sts):
                # probe per request (not just lane 0 — a batch whose first
                # request lacks cross K/V must not drop everyone else's);
                # lanes without it get zero rows, built from shape metadata
                # only (no device->host transfer of present entries)
                for name in ("xk", "xv"):
                    ref = next(st[f"{name}{i}"] for st in sts
                               if f"{name}{i}" in st)
                    zero = None
                    per = []
                    for st in sts:
                        e = st.get(f"{name}{i}")
                        if e is None:
                            if zero is None:
                                zero = np.zeros(ref.shape, np.float32)
                            e = zero
                        per.append(e)
                    ent[name] = stack(per)
            out.append(ent)
        return {"layers": out}

    def _commit_paged(self, rids, new_paged, new_state, lens):
        """Adopt the (donated) cache buffers and scatter back the small
        per-request state; block tables/lengths advance by one token."""
        for name, cache in (("kv", self.caches.kv), ("mla", self.caches.mla)):
            if name in new_paged:
                cache.data = new_paged[name]
                cache.commit_decode(rids)
        for b, rid in enumerate(rids):
            st = self.caches.states.get(rid) or {}
            for i, kind in enumerate(self.cfg.layer_kinds()):
                if kind in (MAMBA1, MAMBA2):
                    e = new_state["layers"][i]
                    st[f"mamba{i}"] = {"state": e["state"][b:b + 1],
                                      "conv": e["conv"][b:b + 1]}
            st["ctx_len"] = lens[b] + 1
            self.caches.states.put(rid, st)

    def _decode_paged(self, rids, tokens: np.ndarray, sample=None):
        data, ctl, state, lens_arr, lens = self._prepare_paged(rids)
        B_pad = lens_arr.shape[0]
        greedy = self._all_greedy(sample)
        if sample is not None and not greedy:
            ctl["sample"] = self._sample_ctl(sample, B_pad)
        tok = np.zeros((B_pad, 1), np.int32)
        tok[:len(rids), 0] = tokens
        out, new_paged, new_state = self._paged_jit(
            self.params, data, ctl, state, lens_arr, jnp.asarray(tok))
        self._commit_paged(rids, new_paged, new_state, lens)
        if greedy:
            out = self._argmax_jit(out)
        return np.asarray(out[:len(rids)])

    def _scatter_decoded(self, rids, new_cache, lens):
        cfg = self.cfg
        lens = np.asarray(lens)
        for b, rid in enumerate(rids):
            one = {"layers": []}
            for i, kind in enumerate(cfg.layer_kinds()):
                e = new_cache["layers"][i]
                if kind in (MAMBA1, MAMBA2):
                    one["layers"].append(
                        {"state": jnp.asarray(e["state"][b:b + 1]),
                         "conv": jnp.asarray(e["conv"][b:b + 1])})
                else:
                    ent = {}
                    for name, a in e.items():
                        if name in ("xk", "xv"):
                            continue
                        # the newly written token sits at position lens[b]
                        ent[name] = a[b:b + 1, lens[b]:lens[b] + 1]
                    one["layers"].append(ent)
            self._append_entries(rid, one)
            st = self.caches.states.get(rid) or {}
            st["ctx_len"] = int(lens[b]) + 1
            self.caches.states.put(rid, st)

    # ------------------------------------------------------------------
    # fused encode+decode (multi-stream analogue; paper §3.1 / Fig 4)
    # ------------------------------------------------------------------
    def _joint_fn(self, params, media, cache, lens, tok):
        emb = M.encode_media(self.cfg, params, media)
        logits, new_cache = M.decode_step(self.cfg, params, cache, lens, tok)
        return emb, logits, new_cache

    def _joint_paged_fn(self, params, media, data, ctl, state, lens, tok):
        emb = M.encode_media(self.cfg, params, media)
        logits, new_paged, new_state = M.decode_step_paged(
            self.cfg, params, data, ctl, state, lens, tok,
            attn_impl=self.attn_impl)
        return emb, logits, new_paged, new_state

    def joint_encode_decode(self, enc_items, rids, tokens, sample=None):
        """Encode a media batch AND decode a token batch in one jitted
        computation so XLA overlaps MXU-bound encode with HBM-bound decode.

        Returns the decode logits [len(rids), V] (np) — or the sampled
        next-token ids [len(rids)] when ``sample`` is given — or None when
        there was no decode work.  The embeddings land in the image cache /
        state store via ``_store_encoded`` — on device caches they never
        cross the host boundary, so they are deliberately NOT returned
        (every caller only consumes the logits)."""
        if not enc_items:
            return self.decode(rids, tokens, sample)
        if not rids:
            self.encode(enc_items)
            return None
        if len({m.shape for _, m in enc_items}) > 1:
            # mixed media shapes can't stack into one encode batch: run the
            # (shape-grouped) encode separately and decode as usual
            self.encode(enc_items)
            return self.decode(rids, tokens, sample)
        media = self._media_batch(enc_items)
        greedy = self._all_greedy(sample)
        if self.caches.device:
            data, ctl, state, lens_arr, lens = self._prepare_paged(rids)
            B_pad = lens_arr.shape[0]
            if sample is not None and not greedy:
                ctl["sample"] = self._sample_ctl(sample, B_pad)
            tok = np.zeros((B_pad, 1), np.int32)
            tok[:len(rids), 0] = tokens
            emb, out, new_paged, new_state = self._joint_paged_jit(
                self.params, media, data, ctl, state, lens_arr,
                jnp.asarray(tok))
            self._store_encoded(enc_items, emb)
            self._commit_paged(rids, new_paged, new_state, lens)
            if greedy:
                out = self._argmax_jit(out)
            return np.asarray(out[:len(rids)])
        cache, lens = self._batched_cache(rids)
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        emb, logits, new_cache = self._joint_jit(self.params, media, cache,
                                                 lens, tok)
        self._store_encoded(enc_items, np.asarray(emb))
        self._scatter_decoded(rids, new_cache, lens)
        if sample is not None:
            if greedy:
                return np.asarray(self._argmax_jit(logits))
            return np.asarray(self._sample_jit(
                logits, self._sample_ctl(sample, len(rids))))
        return np.asarray(logits)
