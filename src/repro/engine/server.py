"""HydraServer: real-execution multi-instance serving (in-process).

The same scheduling stack as the simulator — Algorithm 1 / baseline
policies, pull-based migration, hybrid EPD instance roles — but stages
execute for real through ModelRunner on actual JAX model weights, and time
is wall-clock.  This is the engine behind examples/quickstart.py and the
end-to-end integration tests; the paper-scale experiments use the
discrete-event simulator with the identical scheduling code.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_scheduler import POLICIES
from repro.core.budgets import Budgets
from repro.core.request import Request, SLO, Stage
from repro.core.simulator import ROLE_SETS, DisaggConfig
from repro.engine import runner as R
from repro.engine.paged_cache import PagedCache


@dataclass
class ServeItem:
    req: Request
    prompt: np.ndarray                 # [n_text] int32
    media: Optional[np.ndarray] = None  # [n_media, d_model]
    generated: list = field(default_factory=list)


class RealInstance:
    """Duck-types the fields the scheduling policies expect."""

    def __init__(self, iid, role_name, cfg, params, budgets, policy,
                 *, kv_blocks=512, img_blocks=16):
        self.iid = iid
        self.role_name = role_name
        self.role = ROLE_SETS[role_name]
        self.budgets = budgets
        self.policy = policy
        self.caches = R.RunnerCaches(cfg, kv_blocks=kv_blocks,
                                     img_blocks=img_blocks)
        self.runner = R.ModelRunner(cfg, params, self.caches)
        self.running: list[Request] = []
        self.waiting: deque = deque()

    def enqueue(self, r: Request, pull_bytes: float = 0.0):
        self.waiting.append((r, pull_bytes))

    def has_capacity(self, r: Request) -> bool:
        if r.stage in (Stage.PREFILL, Stage.DECODE):
            need = r.prefill_remaining + r.max_new_tokens + 1
            return self.caches.kv_tokens_free() >= need
        if r.stage == Stage.ENCODE and self.caches.img is not None:
            return self.caches.img.can_fit(r.image_tokens)
        return True

    def pop_waiting(self, stage, now):
        for i, (r, pull) in enumerate(self.waiting):
            if stage is not None and r.stage != stage:
                continue
            if not self.has_capacity(r):
                continue
            del self.waiting[i]
            self.running.append(r)
            self._pending_pull = (r, pull)
            return r
        return None

    def remove(self, r: Request):
        if r in self.running:
            self.running.remove(r)


class HydraServer:
    def __init__(self, cfg: ModelConfig, params, disagg: DisaggConfig, *,
                 slo: SLO = SLO(10.0, 1.0), policy: str = "hydra",
                 budgets: Budgets = Budgets(64, 4), kv_blocks: int = 512,
                 img_blocks: int = 16):
        self.cfg = cfg
        pol = POLICIES[policy]
        self.instances = []
        iid = itertools.count()
        # real execution runs on the host device: RoleSpec hardware
        # overrides only affect the simulator's cost model
        for role, spec in disagg.roles:
            for _ in range(spec.count):
                self.instances.append(RealInstance(
                    next(iid), role, cfg, params, budgets, pol,
                    kv_blocks=kv_blocks, img_blocks=img_blocks))
        self.items: dict[int, ServeItem] = {}
        self._rid = itertools.count()
        self.slo = slo
        self.migrated_bytes = 0
        self.n_migrations = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, media: Optional[np.ndarray] = None,
               max_new_tokens: int = 16, arrival: float = 0.0) -> int:
        rid = next(self._rid)
        n_media = 0 if media is None else media.shape[0]
        req = Request(rid=rid, arrival=arrival,
                      n_images=1 if n_media else 0, image_tokens=n_media,
                      prompt_tokens=len(prompt),
                      max_new_tokens=max_new_tokens, slo=self.slo,
                      media_in_lm=self.cfg.frontend != "audio")
        self.items[rid] = ServeItem(req=req, prompt=np.asarray(prompt),
                                    media=media)
        inst = self._route(req.stage)
        inst.enqueue(req)
        return rid

    def _route(self, stage: Stage) -> RealInstance:
        cands = [i for i in self.instances if stage in i.role]
        return min(cands, key=lambda i: len(i.running) + len(i.waiting))

    def _migrate(self, r: Request, src: RealInstance):
        src.remove(r)
        dst = self._route(r.stage)
        moved = R.migrate(r.rid, src.caches, dst.caches)
        self.migrated_bytes += moved
        self.n_migrations += 1
        dst.running.append(r)

    # ------------------------------------------------------------------
    def _exec_batch(self, inst: RealInstance, batch, now):
        items = self.items
        # --- encode (+ joint with decode under hydra's parallel streams)
        enc_items = [(r.rid, items[r.rid].media) for r, _ in batch.encode]
        dec_reqs = list(batch.decode)
        joint = (inst.policy.parallel_streams and enc_items and dec_reqs)
        if joint:
            toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
            _, logits = inst.runner.joint_encode_decode(
                enc_items, [r.rid for r in dec_reqs], toks)
        else:
            if enc_items:
                inst.runner.encode(enc_items)
            logits = None
            if dec_reqs:
                toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
                logits = inst.runner.decode([r.rid for r in dec_reqs], toks)
        if dec_reqs and logits is not None:
            nxt = np.argmax(logits, axis=-1)
            for r, t in zip(dec_reqs, nxt):
                items[r.rid].generated.append(int(t))

        # --- encode bookkeeping
        for r, _ in batch.encode:
            if r.stage == Stage.ENCODE:
                r.advance_after_encode()
                if Stage.PREFILL not in inst.role:
                    self._migrate(r, inst)

        # --- chunked prefill (per request; media embeds whole-first)
        for r, chunk in batch.prefill:
            it = items[r.rid]
            if r.media_in_lm and r.prefill_done < r.image_tokens:
                logit = inst.runner.prefill_chunk(r.rid, None, use_media=True)
                done = r.image_tokens
            else:
                t0 = r.prefill_done - (r.image_tokens if r.media_in_lm else 0)
                t1 = min(t0 + chunk, len(it.prompt))
                logit = inst.runner.prefill_chunk(r.rid, it.prompt[t0:t1])
                done = t1 - t0
            r.advance_after_prefill_chunk(done, now)
            if r.stage in (Stage.DECODE, Stage.DONE):
                it.generated.append(int(np.argmax(logit)))
            if r.stage == Stage.DECODE and Stage.DECODE not in inst.role:
                self._migrate(r, inst)
            elif r.stage == Stage.DONE:
                inst.remove(r)

        # --- decode bookkeeping
        for r in dec_reqs:
            r.advance_after_decode_step(now)
            if r.stage == Stage.DONE:
                inst.remove(r)
                inst.caches.free(r.rid)

    # ------------------------------------------------------------------
    def run(self, max_iters: int = 10_000) -> dict:
        t0 = time.monotonic()
        for _ in range(max_iters):
            any_work = False
            for inst in self.instances:
                now = time.monotonic() - t0
                batch = inst.policy.build(inst, now)
                if batch.empty:
                    continue
                any_work = True
                self._exec_batch(inst, batch, time.monotonic() - t0)
            if not any_work:
                if all(not i.waiting and not i.running
                       for i in self.instances):
                    break
        return {rid: it for rid, it in self.items.items()}
