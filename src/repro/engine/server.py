"""HydraServer: real-execution multi-instance serving (in-process).

The same scheduling stack as the simulator — Algorithm 1 / baseline
policies, pull-based migration, hybrid EPD instance roles — but stages
execute for real through ModelRunner on actual JAX model weights, and time
is wall-clock.  This is the engine behind examples/quickstart.py and the
end-to-end integration tests; the paper-scale experiments use the
discrete-event simulator with the identical scheduling code.

Fault tolerance (DESIGN.md §15): every instance carries a health state
machine (healthy → degraded → dead) driven by per-iteration progress
heartbeats; a dead instance is quarantined (removed from routing, its cache
references released) and its stranded requests are re-dispatched to
survivors via journal *replay* — re-prefilling the original prompt plus the
already-emitted output tokens and resuming decode at the exact per-lane PRNG
step, so greedy/seeded continuations are bit-exact with an uninterrupted
run.  Migrations retry with bounded backoff on typed transfer failures
(drop/corrupt/OOM/timeout) before falling back to replay.  Under durably
degraded capacity, deadline-aware shedding (``shed_policy="deadline"``)
finishes doomed requests with reason "error" and rejects unserveable
submits with a typed ``AdmissionError``.  A seeded ``FaultPlan`` injects
crashes, stalls, allocation failures, and transfer faults at chosen
scheduler iterations for testing and the recovery benchmark.
"""
from __future__ import annotations

import hashlib
import itertools
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field, replace as dataclasses_replace
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_scheduler import POLICIES
from repro.core.budgets import Budgets
from repro.core.costmodel import A100
from repro.core.request import (Request, SLO, SamplingParams, Stage,
                                StreamEvent)
from repro.core.simulator import ROLE_SETS, DisaggConfig
from repro.engine import runner as R
from repro.engine.faults import (AdmissionError, FaultPlan, RequestJournal,
                                 TransferError)


@dataclass
class ServeItem:
    req: Request
    prompt: np.ndarray                 # [n_text] int32
    media: Optional[list] = None       # [per image: [n_media_i, d_model]]
    generated: list = field(default_factory=list)
    seed: int = 0                      # resolved sampling seed
    # --- prefix/embedding cache bookkeeping (DESIGN.md §14) ---
    kv_keys: Optional[list] = None     # live seq-cache key stream: media
    #                                    pseudo-keys then prompt tokens,
    #                                    extended with each decoded token
    kv_root: int = 0                   # chain root seed (mixes media for
    #                                    cross-attn archs)
    img_keys: Optional[list] = None    # image-cache key stream
    media_hashes: Optional[list] = None  # per-image content hashes
    cached_media: Optional[list] = None  # embeddings found in the encode
    #                                      cache at submit (pinned here so
    #                                      LRU eviction can't race install)
    media_installed: bool = False
    # --- failure recovery (DESIGN.md §15) ---
    journal: Optional[RequestJournal] = None  # original prompt + media
    #                                           hashes + seed; ``generated``
    #                                           above is the accepted-token
    #                                           half of the journal


def _media_hash(m) -> int:
    """Content hash of one media array (the identity under which its
    encoded embedding and its cache pages are shared across requests)."""
    a = np.ascontiguousarray(np.asarray(m))
    h = hashlib.blake2b(digest_size=8)
    h.update(str((a.shape, a.dtype.str)).encode())
    h.update(a.tobytes())
    return int.from_bytes(h.digest(), "little")


class EmbeddingCache:
    """Content-hash -> encoded media embedding (host numpy), LRU-bounded.

    A hit lets a repeated image/clip skip the encode stage entirely: the
    stored embedding is installed straight into the image cache (sharing
    resident pages by the same hash) or the cross-attn state store.
    """

    def __init__(self, capacity: int = 32):
        self.capacity = capacity
        self.store: "OrderedDict[int, np.ndarray]" = OrderedDict()

    def get(self, h: int):
        e = self.store.get(h)
        if e is not None:
            self.store.move_to_end(h)
        return e

    def put(self, h: int, emb: np.ndarray):
        if h in self.store:
            self.store.move_to_end(h)
            return
        self.store[h] = emb
        while len(self.store) > self.capacity:
            self.store.popitem(last=False)


class RealInstance:
    """Duck-types the fields the scheduling policies expect.

    Unlike the simulator's ``Instance`` there is no pull-delay modeling
    here: real migration happens synchronously in ``HydraServer._migrate``
    (which accounts the actual bytes moved), so the queue holds bare
    requests.
    """

    def __init__(self, iid, role_name, cfg, params, budgets, policy,
                 *, kv_blocks=512, img_blocks=16, device_cache=True,
                 spec=None, sharing=False):
        self.iid = iid
        self.role_name = role_name
        self.role = ROLE_SETS[role_name]
        self.budgets = budgets
        self.policy = policy
        self.spec = spec                    # RoleSpec (hw/tp routing weights)
        self.caches = R.RunnerCaches(cfg, kv_blocks=kv_blocks,
                                     img_blocks=img_blocks,
                                     device=device_cache, sharing=sharing)
        self.runner = R.ModelRunner(cfg, params, self.caches)
        self.running: list[Request] = []
        self.waiting: deque = deque()
        # health state machine (DESIGN.md §15): healthy -> degraded -> dead
        self.health = "healthy"
        self.stall_count = 0         # consecutive no-progress iterations

    def enqueue(self, r: Request):
        self.waiting.append(r)

    def _kv_reserved(self) -> int:
        """KV tokens promised to already-admitted requests but not yet
        written, plus one block of rounding slack each — without this,
        several requests can each pass ``has_capacity`` against the same
        free pool and then OOM the allocator mid-run.  Encode-stage
        requests count too when this instance will also prefill them:
        ``advance_after_encode`` flips them to PREFILL with no further
        capacity check."""
        tot = 0
        for r in self.running:
            if r.stage in (Stage.PREFILL, Stage.DECODE):
                tot += (r.prefill_remaining
                        + max(r.max_new_tokens - r.tokens_out, 0)
                        + 1 + R.KV_BLOCK)
            elif r.stage == Stage.ENCODE and Stage.PREFILL in self.role:
                tot += r.prefill_total + r.max_new_tokens + 1 + R.KV_BLOCK
        return tot

    @staticmethod
    def _needs_media_install(r: Request) -> bool:
        """An encode-skipped vision request whose cached embeddings have not
        landed in the image cache yet (they install lazily at its first
        prefill batch; a full KV-prefix hit over the media span skips the
        install entirely, hence the prefill_done test)."""
        return (r.stage == Stage.PREFILL and r.encode_cached
                and r.media_in_lm and r.prefill_done < r.image_tokens)

    def _img_reserved_blocks(self) -> int:
        """Image blocks promised to admitted requests whose media has not
        materialized yet (same double-admission hazard as KV): encode-stage
        requests, plus encode-skipped ones pending their lazy install."""
        bs = self.caches.img.spec.block_size
        return sum(-(-r.image_tokens // bs) for r in self.running
                   if r.stage == Stage.ENCODE or self._needs_media_install(r))

    def has_capacity(self, r: Request) -> bool:
        if r.stage in (Stage.PREFILL, Stage.DECODE):
            need = r.prefill_remaining + r.max_new_tokens + 1 + R.KV_BLOCK
            if self.caches.kv_tokens_free() < need + self._kv_reserved():
                return False
            if self._needs_media_install(r) and self.caches.img is not None:
                bs = self.caches.img.spec.block_size
                need_img = -(-r.image_tokens // bs)
                return (self.caches.img.available_blocks
                        >= need_img + self._img_reserved_blocks())
            return True
        if r.stage == Stage.ENCODE and self.caches.img is not None:
            bs = self.caches.img.spec.block_size
            need = -(-r.image_tokens // bs)
            if (self.caches.img.available_blocks
                    < need + self._img_reserved_blocks()):
                return False
            if Stage.PREFILL in self.role:  # will prefill here post-encode
                need_kv = r.prefill_total + r.max_new_tokens + 1 + R.KV_BLOCK
                return (self.caches.kv_tokens_free()
                        >= need_kv + self._kv_reserved())
            return True
        return True

    def pop_waiting(self, stage, now):
        for i, r in enumerate(self.waiting):
            if stage is not None and r.stage != stage:
                continue
            if not self.has_capacity(r):
                continue
            del self.waiting[i]
            self.running.append(r)
            return r
        return None

    def remove(self, r: Request):
        if r in self.running:
            self.running.remove(r)


class HydraServer:
    def __init__(self, cfg: ModelConfig, params, disagg: DisaggConfig, *,
                 slo: SLO = SLO(10.0, 1.0), policy: str = "hydra",
                 budgets: Budgets = Budgets(64, 4), kv_blocks: int = 512,
                 img_blocks: int = 16, device_cache: bool = True,
                 prefix_cache: bool = False, embed_cache_entries: int = 32,
                 fault_plan: Optional[FaultPlan] = None,
                 shed_policy: str = "off", shed_ttft_factor: float = 8.0,
                 transfer_retries: int = 3, transfer_backoff: float = 0.005,
                 transfer_timeout: Optional[float] = None,
                 degraded_after: Optional[int] = 8,
                 dead_after: Optional[int] = 32, max_recoveries: int = 5):
        self.cfg = cfg
        pol = POLICIES[policy]
        self.instances = []
        iid = itertools.count()
        # real execution runs on the host device: RoleSpec hardware
        # overrides only feed the speed-normalized router below
        for role, spec in disagg.roles:
            for _ in range(spec.count):
                self.instances.append(RealInstance(
                    next(iid), role, cfg, params, budgets, pol,
                    kv_blocks=kv_blocks, img_blocks=img_blocks,
                    device_cache=device_cache, spec=spec,
                    sharing=prefix_cache))
        self.items: dict[int, ServeItem] = {}
        self._rid = itertools.count()
        self.slo = slo
        self.migrated_bytes = 0
        self.n_migrations = 0
        self.on_event = None            # callable(StreamEvent) | None
        self.prefix_cache = prefix_cache
        self.embed_cache = EmbeddingCache(embed_cache_entries)
        self.cache_counters = {"prompt_tokens": 0, "cached_prompt_tokens": 0,
                               "images": 0, "cached_images": 0}
        # --- fault tolerance (DESIGN.md §15) ---
        if shed_policy not in ("off", "deadline"):
            raise ValueError(f"unknown shed_policy {shed_policy!r}")
        self.fault_plan = fault_plan
        self.shed_policy = shed_policy
        self.shed_ttft_factor = shed_ttft_factor
        self.transfer_retries = transfer_retries
        self.transfer_backoff = transfer_backoff
        self.transfer_timeout = transfer_timeout
        self.degraded_after = degraded_after
        self.dead_after = dead_after
        self.max_recoveries = max_recoveries
        self.dead_instances: list[RealInstance] = []
        self.fault_log: list[dict] = []
        self._iter = 0                 # productive scheduler iterations
        self.n_replays = 0
        self.n_shed = 0
        self.n_transfer_retries = 0
        self.n_transfer_failures = 0
        self._t0 = time.monotonic()

    def now(self) -> float:
        """Engine clock: seconds since server construction."""
        return time.monotonic() - self._t0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, media=None,
               max_new_tokens: Optional[int] = None, arrival: float = 0.0,
               sampling: Optional[SamplingParams] = None,
               slo: Optional[SLO] = None) -> int:
        """Enqueue a request.  Legal at any time, including while the serve
        loop is live (open-loop arrivals through ``Engine``).

        ``media``: None, one [n_media, d_model] array (a single image /
        audio clip), or a list of such arrays for multi-image requests
        (LLaVA-Next / Qwen2-VL style) — each counts as one image and its
        rows as image tokens.  ``sampling`` defaults to greedy;
        ``max_new_tokens`` (legacy) overrides ``sampling.max_tokens``.
        """
        rid = next(self._rid)
        if media is not None and not isinstance(media, (list, tuple)):
            media = [media]
        media = list(media) if media else None
        n_images = len(media) if media else 0
        image_tokens = sum(m.shape[0] for m in media) if media else 0
        if sampling is None:
            sampling = SamplingParams(
                max_tokens=16 if max_new_tokens is None else max_new_tokens)
        elif max_new_tokens is not None:
            sampling = dataclasses_replace(sampling,
                                           max_tokens=max_new_tokens)
        req = Request(rid=rid, arrival=arrival,
                      n_images=n_images, image_tokens=image_tokens,
                      prompt_tokens=len(prompt),
                      max_new_tokens=sampling.max_tokens,
                      slo=slo or self.slo, sampling=sampling,
                      media_in_lm=self.cfg.frontend != "audio")
        if self.shed_policy == "deadline":
            self._admission_check(req)     # typed reject before any state
        seed = sampling.seed if sampling.seed is not None \
            else (rid * 1000003 + 99991) & 0x7FFFFFFF
        it = ServeItem(req=req, prompt=np.asarray(prompt), media=media,
                       seed=seed)
        self.items[rid] = it
        if self.prefix_cache:
            self._prepare_cache_keys(it)
        if media is not None and it.media_hashes is None:
            it.media_hashes = [_media_hash(m) for m in media]
        it.journal = RequestJournal(
            prompt=np.array(it.prompt, copy=True),
            media_hashes=tuple(it.media_hashes or ()), seed=seed)
        inst = self._route(req.stage)
        self._bind_keys(inst, it)
        if req.stage == Stage.PREFILL:
            self._try_prefix_match(inst, it)
        inst.enqueue(req)
        return rid

    # ------------------------------------------------------------------
    # prefix / image-embedding caching (DESIGN.md §14)
    # ------------------------------------------------------------------
    def _prepare_cache_keys(self, it: ServeItem):
        """Derive the request's cache identity once, at submit: the seq-cache
        key stream (media pseudo-keys then prompt tokens — decoded tokens
        append later), and the encode-skip decision when every media item's
        embedding is already resident in the embedding cache."""
        r = it.req
        prompt = [int(t) for t in it.prompt]
        if not it.media:
            it.kv_keys = prompt
            return
        it.media_hashes = [_media_hash(m) for m in it.media]
        self.cache_counters["images"] += len(it.media)
        if r.media_in_lm:
            mkeys = [(h, j) for h, m in zip(it.media_hashes, it.media)
                     for j in range(m.shape[0])]
            it.kv_keys = mkeys + prompt
            it.img_keys = mkeys
        else:
            # cross-attn: media never enters the LM sequence, but every KV
            # row attends enc_out — mix the media identity into the chain
            # root so different clips can never share a text prefix
            it.kv_keys = prompt
            it.kv_root = hash(("xattn", tuple(it.media_hashes)))
        cached = [self.embed_cache.get(h) for h in it.media_hashes]
        if all(c is not None for c in cached):
            it.cached_media = cached       # pin vs. LRU eviction
            r.encode_cached = True
            r.stage = Stage.PREFILL        # skip the encode stage entirely
            self.cache_counters["cached_images"] += len(it.media)

    def _bind_keys(self, inst: RealInstance, it: ServeItem):
        """Attach the request's live key streams to an instance's sharing
        caches so commits register completed blocks (idempotent)."""
        if not self.prefix_cache:
            return
        rid = it.req.rid
        for c in (inst.caches.kv, inst.caches.mla):
            if c is not None and c.sharing and it.kv_keys is not None:
                c.set_keys(rid, it.kv_keys, it.kv_root)
        if inst.caches.img is not None and it.img_keys is not None:
            inst.caches.img.set_keys(rid, it.img_keys, 0)

    def _try_prefix_match(self, inst: RealInstance, it: ServeItem):
        """Adopt the longest resident KV prefix for a PREFILL-stage request
        before it is scheduled, so chunk planning and capacity reservations
        see only the miss suffix.  Capped at prefill_total - 1 (the suffix
        chunk must run to produce the first-token logits); media-in-LM
        prompts must cover the whole media span or nothing, because media
        chunks embed whole-first."""
        if not self.prefix_cache:
            return
        r = it.req
        if r.stage != Stage.PREFILL or r.prefill_done:
            return
        pools = [c for c in (inst.caches.kv, inst.caches.mla)
                 if c is not None]
        if not pools or not all(c.sharing for c in pools):
            matched = 0                    # SSM-hybrid: sharing gated off
        else:
            limit = r.prefill_total - 1
            matched = min(c.probe_prefix(it.kv_keys, it.kv_root, limit)
                          for c in pools)
            if r.media_in_lm and 0 < matched < r.image_tokens:
                matched = 0
        self.cache_counters["prompt_tokens"] += r.prefill_total
        if matched <= 0:
            return
        for c in pools:
            c.take_prefix(r.rid, matched, it.kv_keys, it.kv_root)
        r.prefill_done = matched
        r.prefix_cached_tokens = matched
        self.cache_counters["cached_prompt_tokens"] += matched

    def _cache_encoded(self, inst: RealInstance, r: Request):
        """After a real encode: publish the per-media embeddings into the
        content-hash embedding cache so later requests can skip the stage.
        Cross-attn encoders may change sequence length, so their output is
        only cacheable when the clip boundary is unambiguous (single clip)."""
        it = self.items[r.rid]
        if it.media_hashes is None:
            return
        if self.cfg.cross_attention:
            if len(it.media_hashes) != 1:
                return
            st = inst.caches.states.get(r.rid) or {}
            enc = st.get("enc_out")
            if enc is not None:
                self.embed_cache.put(it.media_hashes[0], np.asarray(enc))
            return
        emb = np.asarray(inst.caches.img.gather(r.rid)[0, 0])
        pos = 0
        for h, m in zip(it.media_hashes, it.media):
            n = m.shape[0]
            self.embed_cache.put(h, emb[pos:pos + n])
            pos += n

    def _install_media(self, inst: RealInstance, it: ServeItem):
        """Lazily materialize an encode-skipped request's media on its
        prefill instance: enc_out into the state store (cross-attn), or the
        cached embeddings into the paged image cache — adopting resident
        pages by content hash first, appending only the miss remainder."""
        r = it.req
        if self.cfg.cross_attention:
            st = inst.caches.states.get(r.rid) or {}
            e = it.cached_media[0] if len(it.cached_media) == 1 else \
                np.concatenate([np.asarray(c) for c in it.cached_media], 0)
            st["enc_out"] = np.asarray(e)
            inst.caches.states.put(r.rid, st)
        else:
            img = inst.caches.img
            matched = img.probe_prefix(it.img_keys, 0, len(it.img_keys))
            if matched:
                img.take_prefix(r.rid, matched, it.img_keys, 0)
            pos = 0
            for e in it.cached_media:
                n = e.shape[0]
                if pos + n > matched:      # miss remainder, in order
                    img.append(r.rid, np.asarray(e)[None, None])
                pos += n
        it.media_installed = True

    def cache_stats(self) -> dict:
        """Hit-rate + sharing counters (feed ``core.costmodel.CacheFeedback``
        and the BENCH_cache scenario)."""
        c = dict(self.cache_counters)
        c["prefix_hit_rate"] = (c["cached_prompt_tokens"] / c["prompt_tokens"]
                                if c["prompt_tokens"] else 0.0)
        c["encode_hit_rate"] = (c["cached_images"] / c["images"]
                                if c["images"] else 0.0)
        cow = ev = 0
        for i in self.instances:
            for cache in (i.caches.kv, i.caches.mla, i.caches.img):
                if cache is not None:
                    cow += cache.n_cow
                    ev += cache.n_evictions
        c["cow_copies"] = cow
        c["evictions"] = ev
        return c

    def abort(self, rid: int, now: Optional[float] = None) -> bool:
        """Cancel a request at any stage: drop it from whichever instance
        holds it (running or waiting) and free its KV/image blocks there.
        Returns False if the rid is unknown or already finished."""
        it = self.items.get(rid)
        if it is None or it.req.done:
            return False
        r = it.req
        now = self.now() if now is None else now
        for inst in self.instances:
            if r in inst.running:
                inst.running.remove(r)
            try:
                inst.waiting.remove(r)
            except ValueError:
                pass
            inst.caches.release(rid)
        r.finish("abort", now)
        self._emit("finish", r, now, finish_reason="abort")
        return True

    @staticmethod
    def _speed(inst: RealInstance, stage: Stage) -> float:
        """Relative service speed for a stage (simulator ``Cluster._speed``):
        decode is bandwidth-bound, encode/prefill compute-bound (paper
        §3.1).  RoleSpec hardware overrides are normalized against the A100
        profile; instances without an override weigh 1.0."""
        spec = inst.spec
        if spec is None or spec.hw is None:
            return float(spec.tp) if spec is not None and spec.tp else 1.0
        tp = spec.tp or 1
        if stage == Stage.DECODE:
            return spec.hw.hbm_bw * tp / A100.hbm_bw
        return spec.hw.peak_flops * tp / A100.peak_flops

    def _route(self, stage: Stage, *, prefer_healthy: bool = True
               ) -> RealInstance:
        """Least outstanding work normalized by instance speed, so
        heterogeneous role groups fill proportionally to capacity.  Healthy
        instances win over degraded ones; raises a typed
        :class:`AdmissionError` when no live instance serves the stage."""
        cands = [i for i in self.instances if stage in i.role]
        if not cands:
            raise AdmissionError(
                f"no live instance serves stage {stage.value!r}")
        if prefer_healthy:
            healthy = [i for i in cands if i.health == "healthy"]
            cands = healthy or cands
        return min(cands, key=lambda i: ((len(i.running) + len(i.waiting) + 1)
                                         / self._speed(i, stage)))

    def _admission_check(self, req: Request):
        """Deadline-aware admission (``shed_policy="deadline"``): reject —
        with a typed error instead of queueing forever — a request whose
        pipeline stages have no live instance or whose KV footprint exceeds
        every candidate instance's whole pool."""
        stages = ([Stage.ENCODE] if req.n_images else []) + [Stage.PREFILL]
        if req.max_new_tokens > 1:
            stages.append(Stage.DECODE)
        for st in stages:
            if not any(st in i.role for i in self.instances):
                raise AdmissionError(
                    f"no live instance serves stage {st.value!r}")
        need = req.prefill_total + req.max_new_tokens + 1 + R.KV_BLOCK
        fits = [i for i in self.instances if Stage.PREFILL in i.role
                and i.caches.kv_tokens_total() >= need]
        if not fits:
            raise AdmissionError(
                f"request needs {need} KV tokens but no live prefill "
                f"instance can ever hold it")

    def _migrate(self, r: Request, src: RealInstance):
        """Hand ``r`` off to an instance of its next stage.  Transfers are
        transactional + checksummed (``paged_cache.migrate_request``); typed
        failures retry with exponential backoff against a (possibly
        different) destination — the source copy survives until an attempt
        fully lands.  Exhausted retries release the source and fall back to
        journal replay, so the request is never lost (DESIGN.md §15)."""
        src.remove(r)
        it = self.items[r.rid]
        last_kind = "?"
        for attempt in range(self.transfer_retries + 1):
            try:
                dst = self._route(r.stage)
            except AdmissionError:
                break                      # no live destination: replay/shed
            # bind keys BEFORE the transfer so the destination's import
            # registers the migrated full blocks in its prefix index
            self._bind_keys(dst, it)
            fault = (self.fault_plan.transfer_fault(self._iter, attempt)
                     if self.fault_plan is not None else None)
            try:
                moved = R.migrate(r.rid, src.caches, dst.caches,
                                  fault=fault, timeout=self.transfer_timeout)
            except TransferError as e:
                last_kind = e.kind
                self.n_transfer_retries += 1
                dst.caches.release(r.rid)  # clear any bound-but-unused keys
                self._log("transfer_retry", rid=r.rid, fault=e.kind,
                          attempt=attempt, dst=dst.iid)
                if attempt < self.transfer_retries:
                    time.sleep(min(self.transfer_backoff * (2 ** attempt),
                                   0.05))
                continue
            self.migrated_bytes += moved
            self.n_migrations += 1
            if r.stage == Stage.PREFILL:
                self._try_prefix_match(dst, it)
            # admit only under the destination's capacity reservation; a
            # full destination parks the request in waiting (its migrated
            # cache is already resident there) until pop_waiting finds room
            if dst.has_capacity(r):
                dst.running.append(r)
            else:
                dst.waiting.append(r)
            return
        # retries exhausted (or no destination): the source copy is of no
        # further use — release it and recover via journal replay
        self.n_transfer_failures += 1
        self._log("transfer_failed", rid=r.rid, fault=last_kind)
        src.caches.release(r.rid)
        self._replay(r, self.now())

    # ------------------------------------------------------------------
    # sampling + event plumbing
    # ------------------------------------------------------------------
    def _emit(self, kind: str, r: Request, now: float, *, token=None,
              finish_reason=None):
        if self.on_event is not None:
            self.on_event(StreamEvent(rid=r.rid, kind=kind, t=now,
                                      token=token,
                                      finish_reason=finish_reason))

    def _sample_args(self, reqs) -> dict:
        """Host-side per-lane sampling controls for a batch (consumed by the
        fused ``M.sample_from_logits`` head inside the jitted step).  The
        PRNG step is the index of the token being sampled (``tokens_out``),
        so a request draws the same stream however it is batched."""
        sp = [r.sampling or SamplingParams() for r in reqs]
        return {
            "temp": np.array([s.temperature for s in sp], np.float32),
            "top_k": np.array([s.top_k for s in sp], np.int32),
            "top_p": np.array([s.top_p for s in sp], np.float32),
            "seed": np.array([self.items[r.rid].seed for r in reqs],
                             np.uint32),
            "step": np.array([r.tokens_out for r in reqs], np.int32),
        }

    def _accept_token(self, r: Request, tok: int, now: float,
                      first: bool) -> bool:
        """Record one sampled token; returns True when it is a stop token
        (the stop token itself is not part of the output)."""
        sp = r.sampling
        if sp is not None and sp.stop and tok in sp.stop:
            return True
        it = self.items[r.rid]
        it.generated.append(tok)
        if it.kv_keys is not None:
            it.kv_keys.append(tok)     # key stream stays ahead of the cache
        self._emit("first_token" if first else "token", r, now, token=tok)
        return False

    def _retire(self, inst: RealInstance, r: Request, now: float,
                reason: Optional[str] = None):
        """A request reached DONE on ``inst``: release its slot and its
        KV/image blocks (on EVERY path, incl. prefill-produced DONE) and
        emit the finish event."""
        if reason is not None:
            r.finish(reason, now)
        inst.remove(r)
        inst.caches.release(r.rid)
        self._emit("finish", r, now, finish_reason=r.finish_reason)

    # ------------------------------------------------------------------
    def _exec_batch(self, inst: RealInstance, batch, now):
        # ``now`` fed the policy's scheduling decisions; token/finish
        # timestamps re-stamp AFTER each blocking runner call so TTFT/TPOT
        # include the compute that produced the token (the runner returns
        # host numpy, so the device work has completed by then)
        items = self.items
        # --- encode (+ joint with decode under hydra's parallel streams);
        # one encode item per image so multi-image requests batch flat
        enc_items = [(r.rid, m) for r, _ in batch.encode
                     for m in items[r.rid].media]
        dec_reqs = list(batch.decode)
        dec_out = None
        if inst.policy.parallel_streams and enc_items and dec_reqs:
            toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
            dec_out = inst.runner.joint_encode_decode(
                enc_items, [r.rid for r in dec_reqs], toks,
                sample=self._sample_args(dec_reqs))
        else:
            if enc_items:
                inst.runner.encode(enc_items)
            if dec_reqs:
                toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
                dec_out = inst.runner.decode(
                    [r.rid for r in dec_reqs], toks,
                    sample=self._sample_args(dec_reqs))
        t_dec = self.now()

        # --- encode bookkeeping
        for r, _ in batch.encode:
            if r.stage == Stage.ENCODE:
                if self.prefix_cache:
                    self._cache_encoded(inst, r)
                r.advance_after_encode()
                if Stage.PREFILL not in inst.role:
                    self._migrate(r, inst)
                else:
                    self._try_prefix_match(inst, items[r.rid])

        # --- chunked prefill: ONE batched runner call for every request's
        # chunk this iteration (stage-level batching, paper §4) instead of
        # a per-request Python loop; media chunks embed whole-first
        if batch.prefill:
            work = []
            for r, chunk in batch.prefill:
                it = items[r.rid]
                if (it.cached_media is not None and not it.media_installed
                        and (self.cfg.cross_attention
                             or r.prefill_done < r.image_tokens)):
                    self._install_media(inst, it)
                if r.media_in_lm and r.prefill_done < r.image_tokens:
                    work.append((r, None, True, r.image_tokens))
                else:
                    t0 = r.prefill_done - (r.image_tokens if r.media_in_lm
                                           else 0)
                    t1 = min(t0 + chunk, len(it.prompt))
                    work.append((r, it.prompt[t0:t1], False, t1 - t0))
            pre_toks = inst.runner.prefill_chunks(
                [(r.rid, toks, um) for r, toks, um, _ in work],
                sample=self._sample_args([r for r, *_ in work]))
            now = self.now()
            for (r, _, _, done), tok in zip(work, pre_toks):
                was_replay = r.replayed_tokens > 0
                r.advance_after_prefill_chunk(done, now)
                resumed = was_replay and r.replayed_tokens == 0
                if r.stage in (Stage.DECODE, Stage.DONE) and not resumed:
                    # prefill produced the request's first token (a resumed
                    # replay discards this sample: its re-prefill ends at
                    # the last token already emitted before the failure)
                    if self._accept_token(r, int(tok), now, first=True):
                        self._retire(inst, r, now, reason="stop")
                        continue
                if r.stage == Stage.DECODE and Stage.DECODE not in inst.role:
                    self._migrate(r, inst)
                elif r.stage == Stage.DONE:
                    self._retire(inst, r, now)

        # --- decode bookkeeping
        if dec_reqs and dec_out is not None:
            for r, tok in zip(dec_reqs, dec_out):
                if self._accept_token(r, int(tok), t_dec, first=False):
                    self._retire(inst, r, t_dec, reason="stop")
                    continue
                r.advance_after_decode_step(t_dec)
                if r.stage == Stage.DONE:
                    self._retire(inst, r, t_dec)

    # ------------------------------------------------------------------
    # fault tolerance: health tracking, quarantine, journal replay,
    # deadline-aware shedding (DESIGN.md §15)
    # ------------------------------------------------------------------
    def _log(self, kind: str, **kw):
        self.fault_log.append({"t": self.now(), "kind": kind, **kw})

    @staticmethod
    def _has_ready_work(inst: RealInstance, now: float) -> bool:
        return bool(inst.running) or any(r.ready_at <= now
                                         for r in inst.waiting)

    def _health_progress(self, inst: RealInstance):
        if inst.health == "degraded":
            self._log("instance_recovered", iid=inst.iid)
        inst.stall_count = 0
        inst.health = "healthy"

    def _health_no_progress(self, inst: RealInstance, now: float):
        """One missed progress heartbeat: escalate healthy → degraded →
        dead at the configured thresholds (None disables a transition)."""
        inst.stall_count += 1
        if self.dead_after is not None and inst.stall_count >= self.dead_after:
            self._mark_dead(inst, now, cause=(
                f"no progress for {inst.stall_count} iterations"))
        elif (self.degraded_after is not None
              and inst.stall_count >= self.degraded_after
              and inst.health == "healthy"):
            inst.health = "degraded"
            self._log("instance_degraded", iid=inst.iid,
                      stall_count=inst.stall_count)

    def _mark_dead(self, inst: RealInstance, now: float, cause: str = ""):
        """Quarantine a failed instance: remove it from routing, release
        every cache reference it holds, and replay its stranded requests on
        the survivors.  All device state on the instance is considered
        lost."""
        inst.health = "dead"
        if inst in self.instances:
            self.instances.remove(inst)
        self.dead_instances.append(inst)
        stranded = list(inst.running) + list(inst.waiting)
        inst.running.clear()
        inst.waiting.clear()
        for rid in sorted(inst.caches.live_rids()):
            inst.caches.release(rid)
        self._log("instance_dead", iid=inst.iid, cause=cause,
                  stranded=[r.rid for r in stranded])
        for r in stranded:
            if not r.done:
                self._replay(r, now)

    def kill_instance(self, iid: int, now: Optional[float] = None) -> bool:
        """Operator/bench hook: fail instance ``iid`` immediately (same
        path as an injected crash).  Returns False for an unknown iid."""
        for inst in list(self.instances):
            if inst.iid == iid:
                self._mark_dead(inst, self.now() if now is None else now,
                                cause="killed")
                return True
        return False

    def _drop_everywhere(self, r: Request):
        """Remove every trace of ``r`` from live instances (queues + cache
        references).  Defensive: recovery paths must never leave a stale
        copy behind."""
        for inst in self.instances:
            inst.remove(r)
            try:
                inst.waiting.remove(r)
            except ValueError:
                pass
            inst.caches.release(r.rid)

    def _replay(self, r: Request, now: float):
        """Re-dispatch a stranded request from its journal: rebuild the
        prefill context as ``original prompt + generated[:-1]`` so the
        re-prefill ends at the last token already emitted, fast-forward
        ``tokens_out`` (see ``Request.advance_after_prefill_chunk``), and
        resume decode at the exact per-lane PRNG step — bit-exact
        continuation for greedy and seeded sampling.  Surviving prefix /
        embedding-cache blocks make the re-prefill cheap (DESIGN.md §14)."""
        it = self.items[r.rid]
        j = it.journal
        r.n_recoveries += 1
        if r.n_recoveries > self.max_recoveries:
            self._shed(r, now, why="recovery limit exceeded")
            return
        self._drop_everywhere(r)
        if j.media_hashes:
            cur = it.media_hashes if it.media_hashes is not None \
                else [_media_hash(m) for m in it.media]
            if tuple(cur) != tuple(j.media_hashes):
                self._shed(r, now, why="media integrity check failed")
                return
        k = len(it.generated)
        if k > 1:
            it.prompt = np.concatenate(
                [np.asarray(j.prompt),
                 np.asarray(it.generated[:k - 1], dtype=j.prompt.dtype)])
        else:
            it.prompt = np.asarray(j.prompt)
        r.prompt_tokens = len(it.prompt)
        r.replayed_tokens = k
        r.prefill_done = 0
        r.tokens_out = 0
        r.prefix_cached_tokens = 0
        r.ready_at = now
        r.stage = Stage.ENCODE if r.n_images > 0 else Stage.PREFILL
        r.encode_cached = False
        it.media_installed = False
        it.cached_media = None
        if self.prefix_cache and it.media:
            # survivors may still hold the encoded media: re-take the
            # encode-skip decision against the embedding cache
            cached = [self.embed_cache.get(h) for h in it.media_hashes]
            if all(c is not None for c in cached):
                it.cached_media = cached
                r.encode_cached = True
                r.stage = Stage.PREFILL
        try:
            inst = self._route(r.stage)
        except AdmissionError:
            self._shed(r, now, why="no live instance for replay")
            return
        self.n_replays += 1
        self._log("replay", rid=r.rid, tokens_replayed=k, dst=inst.iid)
        self._bind_keys(inst, it)
        if r.stage == Stage.PREFILL:
            self._try_prefix_match(inst, it)
        inst.enqueue(r)

    def _shed(self, r: Request, now: float, why: str = ""):
        """Give up on a request: drop it everywhere, free its blocks, and
        finish it with reason "error" so its stream terminates cleanly."""
        self._drop_everywhere(r)
        self.n_shed += 1
        self._log("shed", rid=r.rid, why=why)
        r.finish("error", now)
        self._emit("finish", r, now, finish_reason="error")

    def _capacity_degraded(self) -> bool:
        return bool(self.dead_instances) or any(i.health != "healthy"
                                                for i in self.instances)

    def _shed_doomed(self, now: float):
        """Deadline-aware shedding (``shed_policy="deadline"``): while
        capacity is durably degraded, queued requests whose TTFT deadline
        is already blown past recovery (``shed_ttft_factor`` x the SLO)
        finish with "error" and free their blocks rather than rotting in a
        queue they will never leave in time."""
        if not self._capacity_degraded():
            return
        for inst in list(self.instances):
            for r in list(inst.waiting):
                if (r.first_token_time is None and r.slo is not None
                        and now - r.arrival
                        > self.shed_ttft_factor * r.slo.ttft):
                    self._shed(r, now, why="TTFT deadline unattainable")

    def _recover_failed_batch(self, inst: RealInstance, batch, now: float):
        """A batch execution died (allocation failure mid-step): the
        touched requests' cache state on ``inst`` is suspect — release and
        replay each of them; the instance itself stays up but takes a
        health strike."""
        reqs = {r.rid: r for r, _ in batch.encode}
        reqs.update({r.rid: r for r, _ in batch.prefill})
        reqs.update({r.rid: r for r in batch.decode})
        self._log("batch_failed", iid=inst.iid, rids=sorted(reqs))
        for r in reqs.values():
            if not r.done:
                inst.remove(r)
                inst.caches.release(r.rid)
                self._replay(r, now)
        self._health_no_progress(inst, now)

    def fault_stats(self) -> dict:
        return {"iterations": self._iter,
                "replays": self.n_replays,
                "shed": self.n_shed,
                "transfer_retries": self.n_transfer_retries,
                "transfer_failures": self.n_transfer_failures,
                "dead_instances": [i.iid for i in self.dead_instances],
                "health": {i.iid: i.health for i in self.instances},
                "log": list(self.fault_log)}

    # ------------------------------------------------------------------
    def _stall_report(self) -> str:
        lines = ["no instance can build a batch but requests remain queued "
                 "(capacity deadlock?)"]
        for i in self.instances:
            free_kv = i.caches.kv_tokens_free()
            img_free = (i.caches.img.available_blocks
                        if i.caches.img is not None else "-")
            lines.append(
                f"  inst {i.iid} [{i.role_name}] health={i.health} "
                f"running={len(i.running)} "
                f"waiting={len(i.waiting)} kv_tokens_free={free_kv} "
                f"img_blocks_free={img_free}")
            for r in list(i.waiting)[:4]:
                lines.append(
                    f"    waiting rid={r.rid} stage={r.stage.value} "
                    f"need={r.prefill_remaining + r.max_new_tokens + 1} "
                    f"ready_at={r.ready_at:.3f}")
        return "\n".join(lines)

    def stall_diagnosis(self) -> tuple:
        """Split the stall guard's diagnostic into its two distinct causes
        (ISSUE 7 satellite): ``("no_progress", msg)`` when some instance
        sits on ready work without executing it (a wedged instance — the
        health state machine's territory), else ``("deadlock", msg)`` for
        the legacy capacity-deadlock report."""
        now = self.now()
        sick = [i for i in self.instances
                if i.stall_count > 0 and self._has_ready_work(i, now)]
        if sick:
            lines = ["instance(s) hold ready work but make no progress "
                     "(wedged instance?)"]
            for i in sick:
                lines.append(
                    f"  inst {i.iid} [{i.role_name}] health={i.health} "
                    f"stall_count={i.stall_count} running={len(i.running)} "
                    f"waiting={len(i.waiting)}")
            return "no_progress", "\n".join(lines)
        return "deadlock", self._stall_report()

    def step(self, now: Optional[float] = None) -> bool:
        """ONE reentrant scheduler iteration: build and execute a batch on
        every instance.  Returns True when any instance had work.  This is
        the serving loop body — ``run()`` iterates it to completion, the
        streaming ``Engine`` drives it continuously while ``submit()`` /
        ``abort()`` land between iterations (continuous batching).

        Fault hooks (DESIGN.md §15): the iteration counter advances only on
        non-idle steps (idle spins between open-loop arrivals don't burn
        fault-plan time); each instance is checked against the plan for
        crashes / stalls / allocation failures, progress heartbeats feed the
        health state machine, and — under ``shed_policy="deadline"`` —
        doomed queued requests are shed after the instance sweep."""
        t = self.now() if now is None else now
        if not self.idle():
            self._iter += 1
        plan = self.fault_plan
        any_work = False
        for inst in list(self.instances):
            if plan is not None and plan.crash(self._iter, inst.iid):
                self._mark_dead(inst, t, cause="injected crash")
                continue
            if plan is not None and plan.stalled(self._iter, inst.iid):
                # wedged: builds nothing this iteration; only count the
                # missed heartbeat when it actually had runnable work
                if self._has_ready_work(inst, t):
                    self._health_no_progress(inst, t)
                continue
            batch = inst.policy.build(inst, t)
            if batch.empty:
                continue
            any_work = True
            inject_alloc = (plan is not None
                            and plan.alloc_fail(self._iter, inst.iid))
            pools = [c for c in (inst.caches.kv, inst.caches.mla,
                                 inst.caches.img) if c is not None]
            if inject_alloc:
                for c in pools:
                    c.fail_alloc = 1
            try:
                self._exec_batch(inst, batch, t)
            except MemoryError:
                self._recover_failed_batch(inst, batch, self.now())
            else:
                self._health_progress(inst)
            finally:
                if inject_alloc:
                    for c in pools:
                        c.fail_alloc = 0
        if self.shed_policy == "deadline":
            self._shed_doomed(self.now() if now is None else now)
        return any_work

    def idle(self) -> bool:
        return all(not i.waiting and not i.running for i in self.instances)

    def deadlock_candidate(self) -> bool:
        """True when pending work exists and ALL of it is ready now: if a
        step still schedules nothing, no amount of waiting can change the
        state (capacity deadlock) — callers count these and raise the
        ``_stall_report`` diagnostic."""
        now = self.now()
        pending = [r for i in self.instances
                   for r in list(i.waiting) + i.running]
        return bool(pending) and all(r.ready_at <= now for r in pending)

    def run(self, max_iters: int = 10_000, stall_iters: int = 100) -> dict:
        """Closed-loop back-compat shim: step until every submitted request
        finishes, with the capacity-deadlock stall guard."""
        stalled = 0
        for _ in range(max_iters):
            if self.step():
                stalled = 0
                continue
            if self.idle():
                break
            # requests remain but nothing was scheduled: if ANY pending
            # request only becomes ready in the future, waiting can
            # still unblock things (e.g. its reservation parks another
            # request) — keep spinning.  If every pending request is
            # ready and still nothing schedules, that is a capacity
            # deadlock: diagnose it instead of silently busy-spinning
            # to max_iters.
            if self.deadlock_candidate():
                stalled += 1
                if stalled >= stall_iters:
                    raise RuntimeError(self.stall_diagnosis()[1])
            else:
                stalled = 0
                time.sleep(0.001)  # future arrival: wait, don't hot-spin
        return {rid: it for rid, it in self.items.items()}
