"""HydraServer: real-execution multi-instance serving (in-process).

The same scheduling stack as the simulator — Algorithm 1 / baseline
policies, pull-based migration, hybrid EPD instance roles — but stages
execute for real through ModelRunner on actual JAX model weights, and time
is wall-clock.  This is the engine behind examples/quickstart.py and the
end-to-end integration tests; the paper-scale experiments use the
discrete-event simulator with the identical scheduling code.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_scheduler import POLICIES
from repro.core.budgets import Budgets
from repro.core.costmodel import A100
from repro.core.request import Request, SLO, Stage
from repro.core.simulator import ROLE_SETS, DisaggConfig
from repro.engine import runner as R


@dataclass
class ServeItem:
    req: Request
    prompt: np.ndarray                 # [n_text] int32
    media: Optional[np.ndarray] = None  # [n_media, d_model]
    generated: list = field(default_factory=list)


class RealInstance:
    """Duck-types the fields the scheduling policies expect.

    Unlike the simulator's ``Instance`` there is no pull-delay modeling
    here: real migration happens synchronously in ``HydraServer._migrate``
    (which accounts the actual bytes moved), so the queue holds bare
    requests.
    """

    def __init__(self, iid, role_name, cfg, params, budgets, policy,
                 *, kv_blocks=512, img_blocks=16, device_cache=True,
                 spec=None):
        self.iid = iid
        self.role_name = role_name
        self.role = ROLE_SETS[role_name]
        self.budgets = budgets
        self.policy = policy
        self.spec = spec                    # RoleSpec (hw/tp routing weights)
        self.caches = R.RunnerCaches(cfg, kv_blocks=kv_blocks,
                                     img_blocks=img_blocks,
                                     device=device_cache)
        self.runner = R.ModelRunner(cfg, params, self.caches)
        self.running: list[Request] = []
        self.waiting: deque = deque()

    def enqueue(self, r: Request):
        self.waiting.append(r)

    def _kv_reserved(self) -> int:
        """KV tokens promised to already-admitted requests but not yet
        written, plus one block of rounding slack each — without this,
        several requests can each pass ``has_capacity`` against the same
        free pool and then OOM the allocator mid-run.  Encode-stage
        requests count too when this instance will also prefill them:
        ``advance_after_encode`` flips them to PREFILL with no further
        capacity check."""
        tot = 0
        for r in self.running:
            if r.stage in (Stage.PREFILL, Stage.DECODE):
                tot += (r.prefill_remaining
                        + max(r.max_new_tokens - r.tokens_out, 0)
                        + 1 + R.KV_BLOCK)
            elif r.stage == Stage.ENCODE and Stage.PREFILL in self.role:
                tot += r.prefill_total + r.max_new_tokens + 1 + R.KV_BLOCK
        return tot

    def _img_reserved_blocks(self) -> int:
        """Image blocks promised to admitted encode requests whose encode
        has not materialized yet (same double-admission hazard as KV)."""
        bs = self.caches.img.spec.block_size
        return sum(-(-r.image_tokens // bs) for r in self.running
                   if r.stage == Stage.ENCODE)

    def has_capacity(self, r: Request) -> bool:
        if r.stage in (Stage.PREFILL, Stage.DECODE):
            need = r.prefill_remaining + r.max_new_tokens + 1 + R.KV_BLOCK
            return self.caches.kv_tokens_free() >= need + self._kv_reserved()
        if r.stage == Stage.ENCODE and self.caches.img is not None:
            bs = self.caches.img.spec.block_size
            need = -(-r.image_tokens // bs)
            if (self.caches.img.allocator.n_free
                    < need + self._img_reserved_blocks()):
                return False
            if Stage.PREFILL in self.role:  # will prefill here post-encode
                need_kv = r.prefill_total + r.max_new_tokens + 1 + R.KV_BLOCK
                return (self.caches.kv_tokens_free()
                        >= need_kv + self._kv_reserved())
            return True
        return True

    def pop_waiting(self, stage, now):
        for i, r in enumerate(self.waiting):
            if stage is not None and r.stage != stage:
                continue
            if not self.has_capacity(r):
                continue
            del self.waiting[i]
            self.running.append(r)
            return r
        return None

    def remove(self, r: Request):
        if r in self.running:
            self.running.remove(r)


class HydraServer:
    def __init__(self, cfg: ModelConfig, params, disagg: DisaggConfig, *,
                 slo: SLO = SLO(10.0, 1.0), policy: str = "hydra",
                 budgets: Budgets = Budgets(64, 4), kv_blocks: int = 512,
                 img_blocks: int = 16, device_cache: bool = True):
        self.cfg = cfg
        pol = POLICIES[policy]
        self.instances = []
        iid = itertools.count()
        # real execution runs on the host device: RoleSpec hardware
        # overrides only feed the speed-normalized router below
        for role, spec in disagg.roles:
            for _ in range(spec.count):
                self.instances.append(RealInstance(
                    next(iid), role, cfg, params, budgets, pol,
                    kv_blocks=kv_blocks, img_blocks=img_blocks,
                    device_cache=device_cache, spec=spec))
        self.items: dict[int, ServeItem] = {}
        self._rid = itertools.count()
        self.slo = slo
        self.migrated_bytes = 0
        self.n_migrations = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, media: Optional[np.ndarray] = None,
               max_new_tokens: int = 16, arrival: float = 0.0) -> int:
        rid = next(self._rid)
        n_media = 0 if media is None else media.shape[0]
        req = Request(rid=rid, arrival=arrival,
                      n_images=1 if n_media else 0, image_tokens=n_media,
                      prompt_tokens=len(prompt),
                      max_new_tokens=max_new_tokens, slo=self.slo,
                      media_in_lm=self.cfg.frontend != "audio")
        self.items[rid] = ServeItem(req=req, prompt=np.asarray(prompt),
                                    media=media)
        inst = self._route(req.stage)
        inst.enqueue(req)
        return rid

    @staticmethod
    def _speed(inst: RealInstance, stage: Stage) -> float:
        """Relative service speed for a stage (simulator ``Cluster._speed``):
        decode is bandwidth-bound, encode/prefill compute-bound (paper
        §3.1).  RoleSpec hardware overrides are normalized against the A100
        profile; instances without an override weigh 1.0."""
        spec = inst.spec
        if spec is None or spec.hw is None:
            return float(spec.tp) if spec is not None and spec.tp else 1.0
        tp = spec.tp or 1
        if stage == Stage.DECODE:
            return spec.hw.hbm_bw * tp / A100.hbm_bw
        return spec.hw.peak_flops * tp / A100.peak_flops

    def _route(self, stage: Stage) -> RealInstance:
        """Least outstanding work normalized by instance speed, so
        heterogeneous role groups fill proportionally to capacity."""
        cands = [i for i in self.instances if stage in i.role]
        return min(cands, key=lambda i: ((len(i.running) + len(i.waiting) + 1)
                                         / self._speed(i, stage)))

    def _migrate(self, r: Request, src: RealInstance):
        src.remove(r)
        dst = self._route(r.stage)
        moved = R.migrate(r.rid, src.caches, dst.caches)
        self.migrated_bytes += moved
        self.n_migrations += 1
        # admit only under the destination's capacity reservation; a full
        # destination parks the request in waiting (its migrated cache is
        # already resident there) until pop_waiting finds room
        if dst.has_capacity(r):
            dst.running.append(r)
        else:
            dst.waiting.append(r)

    # ------------------------------------------------------------------
    def _exec_batch(self, inst: RealInstance, batch, now):
        items = self.items
        # --- encode (+ joint with decode under hydra's parallel streams)
        enc_items = [(r.rid, items[r.rid].media) for r, _ in batch.encode]
        dec_reqs = list(batch.decode)
        joint = (inst.policy.parallel_streams and enc_items and dec_reqs)
        if joint:
            toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
            logits = inst.runner.joint_encode_decode(
                enc_items, [r.rid for r in dec_reqs], toks)
        else:
            if enc_items:
                inst.runner.encode(enc_items)
            logits = None
            if dec_reqs:
                toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
                logits = inst.runner.decode([r.rid for r in dec_reqs], toks)
        if dec_reqs and logits is not None:
            nxt = np.argmax(logits, axis=-1)
            for r, t in zip(dec_reqs, nxt):
                items[r.rid].generated.append(int(t))

        # --- encode bookkeeping
        for r, _ in batch.encode:
            if r.stage == Stage.ENCODE:
                r.advance_after_encode()
                if Stage.PREFILL not in inst.role:
                    self._migrate(r, inst)

        # --- chunked prefill: ONE batched runner call for every request's
        # chunk this iteration (stage-level batching, paper §4) instead of
        # a per-request Python loop; media chunks embed whole-first
        if batch.prefill:
            work = []
            for r, chunk in batch.prefill:
                it = items[r.rid]
                if r.media_in_lm and r.prefill_done < r.image_tokens:
                    work.append((r, None, True, r.image_tokens))
                else:
                    t0 = r.prefill_done - (r.image_tokens if r.media_in_lm
                                           else 0)
                    t1 = min(t0 + chunk, len(it.prompt))
                    work.append((r, it.prompt[t0:t1], False, t1 - t0))
            pre_logits = inst.runner.prefill_chunks(
                [(r.rid, toks, um) for r, toks, um, _ in work])
            for (r, _, _, done), logit in zip(work, pre_logits):
                r.advance_after_prefill_chunk(done, now)
                if r.stage in (Stage.DECODE, Stage.DONE):
                    items[r.rid].generated.append(int(np.argmax(logit)))
                if r.stage == Stage.DECODE and Stage.DECODE not in inst.role:
                    self._migrate(r, inst)
                elif r.stage == Stage.DONE:
                    inst.remove(r)

        # --- decode bookkeeping
        for r in dec_reqs:
            r.advance_after_decode_step(now)
            if r.stage == Stage.DONE:
                inst.remove(r)
                inst.caches.free(r.rid)

    # ------------------------------------------------------------------
    def _stall_report(self) -> str:
        lines = ["no instance can build a batch but requests remain queued "
                 "(capacity deadlock?)"]
        for i in self.instances:
            free_kv = i.caches.kv_tokens_free()
            img_free = (i.caches.img.allocator.n_free
                        if i.caches.img is not None else "-")
            lines.append(
                f"  inst {i.iid} [{i.role_name}] running={len(i.running)} "
                f"waiting={len(i.waiting)} kv_tokens_free={free_kv} "
                f"img_blocks_free={img_free}")
            for r in list(i.waiting)[:4]:
                lines.append(
                    f"    waiting rid={r.rid} stage={r.stage.value} "
                    f"need={r.prefill_remaining + r.max_new_tokens + 1} "
                    f"ready_at={r.ready_at:.3f}")
        return "\n".join(lines)

    def run(self, max_iters: int = 10_000, stall_iters: int = 100) -> dict:
        t0 = time.monotonic()
        stalled = 0
        for _ in range(max_iters):
            any_work = False
            for inst in self.instances:
                now = time.monotonic() - t0
                batch = inst.policy.build(inst, now)
                if batch.empty:
                    continue
                any_work = True
                self._exec_batch(inst, batch, time.monotonic() - t0)
            if not any_work:
                if all(not i.waiting and not i.running
                       for i in self.instances):
                    break
                # requests remain but nothing was scheduled: if ANY pending
                # request only becomes ready in the future, waiting can
                # still unblock things (e.g. its reservation parks another
                # request) — keep spinning.  If every pending request is
                # ready and still nothing schedules, no amount of time can
                # change the state: that is a capacity deadlock, diagnose
                # it instead of silently busy-spinning to max_iters.
                now = time.monotonic() - t0
                pending = [r for i in self.instances
                           for r in list(i.waiting) + i.running]
                if all(r.ready_at <= now for r in pending):
                    stalled += 1
                    if stalled >= stall_iters:
                        raise RuntimeError(self._stall_report())
                else:
                    stalled = 0
                    time.sleep(0.001)  # future arrival: wait, don't hot-spin
            else:
                stalled = 0
        return {rid: it for rid, it in self.items.items()}
