"""HydraServer: real-execution multi-instance serving (in-process).

The same scheduling stack as the simulator — Algorithm 1 / baseline
policies, pull-based migration, hybrid EPD instance roles — but stages
execute for real through ModelRunner on actual JAX model weights, and time
is wall-clock.  This is the engine behind examples/quickstart.py and the
end-to-end integration tests; the paper-scale experiments use the
discrete-event simulator with the identical scheduling code.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field, replace as dataclasses_replace
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_scheduler import POLICIES
from repro.core.budgets import Budgets
from repro.core.costmodel import A100
from repro.core.request import (Request, SLO, SamplingParams, Stage,
                                StreamEvent)
from repro.core.simulator import ROLE_SETS, DisaggConfig
from repro.engine import runner as R


@dataclass
class ServeItem:
    req: Request
    prompt: np.ndarray                 # [n_text] int32
    media: Optional[list] = None       # [per image: [n_media_i, d_model]]
    generated: list = field(default_factory=list)
    seed: int = 0                      # resolved sampling seed


class RealInstance:
    """Duck-types the fields the scheduling policies expect.

    Unlike the simulator's ``Instance`` there is no pull-delay modeling
    here: real migration happens synchronously in ``HydraServer._migrate``
    (which accounts the actual bytes moved), so the queue holds bare
    requests.
    """

    def __init__(self, iid, role_name, cfg, params, budgets, policy,
                 *, kv_blocks=512, img_blocks=16, device_cache=True,
                 spec=None):
        self.iid = iid
        self.role_name = role_name
        self.role = ROLE_SETS[role_name]
        self.budgets = budgets
        self.policy = policy
        self.spec = spec                    # RoleSpec (hw/tp routing weights)
        self.caches = R.RunnerCaches(cfg, kv_blocks=kv_blocks,
                                     img_blocks=img_blocks,
                                     device=device_cache)
        self.runner = R.ModelRunner(cfg, params, self.caches)
        self.running: list[Request] = []
        self.waiting: deque = deque()

    def enqueue(self, r: Request):
        self.waiting.append(r)

    def _kv_reserved(self) -> int:
        """KV tokens promised to already-admitted requests but not yet
        written, plus one block of rounding slack each — without this,
        several requests can each pass ``has_capacity`` against the same
        free pool and then OOM the allocator mid-run.  Encode-stage
        requests count too when this instance will also prefill them:
        ``advance_after_encode`` flips them to PREFILL with no further
        capacity check."""
        tot = 0
        for r in self.running:
            if r.stage in (Stage.PREFILL, Stage.DECODE):
                tot += (r.prefill_remaining
                        + max(r.max_new_tokens - r.tokens_out, 0)
                        + 1 + R.KV_BLOCK)
            elif r.stage == Stage.ENCODE and Stage.PREFILL in self.role:
                tot += r.prefill_total + r.max_new_tokens + 1 + R.KV_BLOCK
        return tot

    def _img_reserved_blocks(self) -> int:
        """Image blocks promised to admitted encode requests whose encode
        has not materialized yet (same double-admission hazard as KV)."""
        bs = self.caches.img.spec.block_size
        return sum(-(-r.image_tokens // bs) for r in self.running
                   if r.stage == Stage.ENCODE)

    def has_capacity(self, r: Request) -> bool:
        if r.stage in (Stage.PREFILL, Stage.DECODE):
            need = r.prefill_remaining + r.max_new_tokens + 1 + R.KV_BLOCK
            return self.caches.kv_tokens_free() >= need + self._kv_reserved()
        if r.stage == Stage.ENCODE and self.caches.img is not None:
            bs = self.caches.img.spec.block_size
            need = -(-r.image_tokens // bs)
            if (self.caches.img.allocator.n_free
                    < need + self._img_reserved_blocks()):
                return False
            if Stage.PREFILL in self.role:  # will prefill here post-encode
                need_kv = r.prefill_total + r.max_new_tokens + 1 + R.KV_BLOCK
                return (self.caches.kv_tokens_free()
                        >= need_kv + self._kv_reserved())
            return True
        return True

    def pop_waiting(self, stage, now):
        for i, r in enumerate(self.waiting):
            if stage is not None and r.stage != stage:
                continue
            if not self.has_capacity(r):
                continue
            del self.waiting[i]
            self.running.append(r)
            return r
        return None

    def remove(self, r: Request):
        if r in self.running:
            self.running.remove(r)


class HydraServer:
    def __init__(self, cfg: ModelConfig, params, disagg: DisaggConfig, *,
                 slo: SLO = SLO(10.0, 1.0), policy: str = "hydra",
                 budgets: Budgets = Budgets(64, 4), kv_blocks: int = 512,
                 img_blocks: int = 16, device_cache: bool = True):
        self.cfg = cfg
        pol = POLICIES[policy]
        self.instances = []
        iid = itertools.count()
        # real execution runs on the host device: RoleSpec hardware
        # overrides only feed the speed-normalized router below
        for role, spec in disagg.roles:
            for _ in range(spec.count):
                self.instances.append(RealInstance(
                    next(iid), role, cfg, params, budgets, pol,
                    kv_blocks=kv_blocks, img_blocks=img_blocks,
                    device_cache=device_cache, spec=spec))
        self.items: dict[int, ServeItem] = {}
        self._rid = itertools.count()
        self.slo = slo
        self.migrated_bytes = 0
        self.n_migrations = 0
        self.on_event = None            # callable(StreamEvent) | None
        self._t0 = time.monotonic()

    def now(self) -> float:
        """Engine clock: seconds since server construction."""
        return time.monotonic() - self._t0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, media=None,
               max_new_tokens: Optional[int] = None, arrival: float = 0.0,
               sampling: Optional[SamplingParams] = None,
               slo: Optional[SLO] = None) -> int:
        """Enqueue a request.  Legal at any time, including while the serve
        loop is live (open-loop arrivals through ``Engine``).

        ``media``: None, one [n_media, d_model] array (a single image /
        audio clip), or a list of such arrays for multi-image requests
        (LLaVA-Next / Qwen2-VL style) — each counts as one image and its
        rows as image tokens.  ``sampling`` defaults to greedy;
        ``max_new_tokens`` (legacy) overrides ``sampling.max_tokens``.
        """
        rid = next(self._rid)
        if media is not None and not isinstance(media, (list, tuple)):
            media = [media]
        media = list(media) if media else None
        n_images = len(media) if media else 0
        image_tokens = sum(m.shape[0] for m in media) if media else 0
        if sampling is None:
            sampling = SamplingParams(
                max_tokens=16 if max_new_tokens is None else max_new_tokens)
        elif max_new_tokens is not None:
            sampling = dataclasses_replace(sampling,
                                           max_tokens=max_new_tokens)
        req = Request(rid=rid, arrival=arrival,
                      n_images=n_images, image_tokens=image_tokens,
                      prompt_tokens=len(prompt),
                      max_new_tokens=sampling.max_tokens,
                      slo=slo or self.slo, sampling=sampling,
                      media_in_lm=self.cfg.frontend != "audio")
        seed = sampling.seed if sampling.seed is not None \
            else (rid * 1000003 + 99991) & 0x7FFFFFFF
        self.items[rid] = ServeItem(req=req, prompt=np.asarray(prompt),
                                    media=media, seed=seed)
        inst = self._route(req.stage)
        inst.enqueue(req)
        return rid

    def abort(self, rid: int, now: Optional[float] = None) -> bool:
        """Cancel a request at any stage: drop it from whichever instance
        holds it (running or waiting) and free its KV/image blocks there.
        Returns False if the rid is unknown or already finished."""
        it = self.items.get(rid)
        if it is None or it.req.done:
            return False
        r = it.req
        now = self.now() if now is None else now
        for inst in self.instances:
            if r in inst.running:
                inst.running.remove(r)
            try:
                inst.waiting.remove(r)
            except ValueError:
                pass
            inst.caches.free(rid)
        r.finish("abort", now)
        self._emit("finish", r, now, finish_reason="abort")
        return True

    @staticmethod
    def _speed(inst: RealInstance, stage: Stage) -> float:
        """Relative service speed for a stage (simulator ``Cluster._speed``):
        decode is bandwidth-bound, encode/prefill compute-bound (paper
        §3.1).  RoleSpec hardware overrides are normalized against the A100
        profile; instances without an override weigh 1.0."""
        spec = inst.spec
        if spec is None or spec.hw is None:
            return float(spec.tp) if spec is not None and spec.tp else 1.0
        tp = spec.tp or 1
        if stage == Stage.DECODE:
            return spec.hw.hbm_bw * tp / A100.hbm_bw
        return spec.hw.peak_flops * tp / A100.peak_flops

    def _route(self, stage: Stage) -> RealInstance:
        """Least outstanding work normalized by instance speed, so
        heterogeneous role groups fill proportionally to capacity."""
        cands = [i for i in self.instances if stage in i.role]
        return min(cands, key=lambda i: ((len(i.running) + len(i.waiting) + 1)
                                         / self._speed(i, stage)))

    def _migrate(self, r: Request, src: RealInstance):
        src.remove(r)
        dst = self._route(r.stage)
        moved = R.migrate(r.rid, src.caches, dst.caches)
        self.migrated_bytes += moved
        self.n_migrations += 1
        # admit only under the destination's capacity reservation; a full
        # destination parks the request in waiting (its migrated cache is
        # already resident there) until pop_waiting finds room
        if dst.has_capacity(r):
            dst.running.append(r)
        else:
            dst.waiting.append(r)

    # ------------------------------------------------------------------
    # sampling + event plumbing
    # ------------------------------------------------------------------
    def _emit(self, kind: str, r: Request, now: float, *, token=None,
              finish_reason=None):
        if self.on_event is not None:
            self.on_event(StreamEvent(rid=r.rid, kind=kind, t=now,
                                      token=token,
                                      finish_reason=finish_reason))

    def _sample_args(self, reqs) -> dict:
        """Host-side per-lane sampling controls for a batch (consumed by the
        fused ``M.sample_from_logits`` head inside the jitted step).  The
        PRNG step is the index of the token being sampled (``tokens_out``),
        so a request draws the same stream however it is batched."""
        sp = [r.sampling or SamplingParams() for r in reqs]
        return {
            "temp": np.array([s.temperature for s in sp], np.float32),
            "top_k": np.array([s.top_k for s in sp], np.int32),
            "top_p": np.array([s.top_p for s in sp], np.float32),
            "seed": np.array([self.items[r.rid].seed for r in reqs],
                             np.uint32),
            "step": np.array([r.tokens_out for r in reqs], np.int32),
        }

    def _accept_token(self, r: Request, tok: int, now: float,
                      first: bool) -> bool:
        """Record one sampled token; returns True when it is a stop token
        (the stop token itself is not part of the output)."""
        sp = r.sampling
        if sp is not None and sp.stop and tok in sp.stop:
            return True
        self.items[r.rid].generated.append(tok)
        self._emit("first_token" if first else "token", r, now, token=tok)
        return False

    def _retire(self, inst: RealInstance, r: Request, now: float,
                reason: Optional[str] = None):
        """A request reached DONE on ``inst``: release its slot and its
        KV/image blocks (on EVERY path, incl. prefill-produced DONE) and
        emit the finish event."""
        if reason is not None:
            r.finish(reason, now)
        inst.remove(r)
        inst.caches.free(r.rid)
        self._emit("finish", r, now, finish_reason=r.finish_reason)

    # ------------------------------------------------------------------
    def _exec_batch(self, inst: RealInstance, batch, now):
        # ``now`` fed the policy's scheduling decisions; token/finish
        # timestamps re-stamp AFTER each blocking runner call so TTFT/TPOT
        # include the compute that produced the token (the runner returns
        # host numpy, so the device work has completed by then)
        items = self.items
        # --- encode (+ joint with decode under hydra's parallel streams);
        # one encode item per image so multi-image requests batch flat
        enc_items = [(r.rid, m) for r, _ in batch.encode
                     for m in items[r.rid].media]
        dec_reqs = list(batch.decode)
        dec_out = None
        if inst.policy.parallel_streams and enc_items and dec_reqs:
            toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
            dec_out = inst.runner.joint_encode_decode(
                enc_items, [r.rid for r in dec_reqs], toks,
                sample=self._sample_args(dec_reqs))
        else:
            if enc_items:
                inst.runner.encode(enc_items)
            if dec_reqs:
                toks = np.array([items[r.rid].generated[-1] for r in dec_reqs])
                dec_out = inst.runner.decode(
                    [r.rid for r in dec_reqs], toks,
                    sample=self._sample_args(dec_reqs))
        t_dec = self.now()

        # --- encode bookkeeping
        for r, _ in batch.encode:
            if r.stage == Stage.ENCODE:
                r.advance_after_encode()
                if Stage.PREFILL not in inst.role:
                    self._migrate(r, inst)

        # --- chunked prefill: ONE batched runner call for every request's
        # chunk this iteration (stage-level batching, paper §4) instead of
        # a per-request Python loop; media chunks embed whole-first
        if batch.prefill:
            work = []
            for r, chunk in batch.prefill:
                it = items[r.rid]
                if r.media_in_lm and r.prefill_done < r.image_tokens:
                    work.append((r, None, True, r.image_tokens))
                else:
                    t0 = r.prefill_done - (r.image_tokens if r.media_in_lm
                                           else 0)
                    t1 = min(t0 + chunk, len(it.prompt))
                    work.append((r, it.prompt[t0:t1], False, t1 - t0))
            pre_toks = inst.runner.prefill_chunks(
                [(r.rid, toks, um) for r, toks, um, _ in work],
                sample=self._sample_args([r for r, *_ in work]))
            now = self.now()
            for (r, _, _, done), tok in zip(work, pre_toks):
                r.advance_after_prefill_chunk(done, now)
                if r.stage in (Stage.DECODE, Stage.DONE):
                    # prefill produced the request's first token
                    if self._accept_token(r, int(tok), now, first=True):
                        self._retire(inst, r, now, reason="stop")
                        continue
                if r.stage == Stage.DECODE and Stage.DECODE not in inst.role:
                    self._migrate(r, inst)
                elif r.stage == Stage.DONE:
                    self._retire(inst, r, now)

        # --- decode bookkeeping
        if dec_reqs and dec_out is not None:
            for r, tok in zip(dec_reqs, dec_out):
                if self._accept_token(r, int(tok), t_dec, first=False):
                    self._retire(inst, r, t_dec, reason="stop")
                    continue
                r.advance_after_decode_step(t_dec)
                if r.stage == Stage.DONE:
                    self._retire(inst, r, t_dec)

    # ------------------------------------------------------------------
    def _stall_report(self) -> str:
        lines = ["no instance can build a batch but requests remain queued "
                 "(capacity deadlock?)"]
        for i in self.instances:
            free_kv = i.caches.kv_tokens_free()
            img_free = (i.caches.img.allocator.n_free
                        if i.caches.img is not None else "-")
            lines.append(
                f"  inst {i.iid} [{i.role_name}] running={len(i.running)} "
                f"waiting={len(i.waiting)} kv_tokens_free={free_kv} "
                f"img_blocks_free={img_free}")
            for r in list(i.waiting)[:4]:
                lines.append(
                    f"    waiting rid={r.rid} stage={r.stage.value} "
                    f"need={r.prefill_remaining + r.max_new_tokens + 1} "
                    f"ready_at={r.ready_at:.3f}")
        return "\n".join(lines)

    def step(self, now: Optional[float] = None) -> bool:
        """ONE reentrant scheduler iteration: build and execute a batch on
        every instance.  Returns True when any instance had work.  This is
        the serving loop body — ``run()`` iterates it to completion, the
        streaming ``Engine`` drives it continuously while ``submit()`` /
        ``abort()`` land between iterations (continuous batching).
        """
        any_work = False
        for inst in self.instances:
            batch = inst.policy.build(inst,
                                      self.now() if now is None else now)
            if batch.empty:
                continue
            any_work = True
            self._exec_batch(inst, batch,
                             self.now() if now is None else now)
        return any_work

    def idle(self) -> bool:
        return all(not i.waiting and not i.running for i in self.instances)

    def deadlock_candidate(self) -> bool:
        """True when pending work exists and ALL of it is ready now: if a
        step still schedules nothing, no amount of waiting can change the
        state (capacity deadlock) — callers count these and raise the
        ``_stall_report`` diagnostic."""
        now = self.now()
        pending = [r for i in self.instances
                   for r in list(i.waiting) + i.running]
        return bool(pending) and all(r.ready_at <= now for r in pending)

    def run(self, max_iters: int = 10_000, stall_iters: int = 100) -> dict:
        """Closed-loop back-compat shim: step until every submitted request
        finishes, with the capacity-deadlock stall guard."""
        stalled = 0
        for _ in range(max_iters):
            if self.step():
                stalled = 0
                continue
            if self.idle():
                break
            # requests remain but nothing was scheduled: if ANY pending
            # request only becomes ready in the future, waiting can
            # still unblock things (e.g. its reservation parks another
            # request) — keep spinning.  If every pending request is
            # ready and still nothing schedules, that is a capacity
            # deadlock: diagnose it instead of silently busy-spinning
            # to max_iters.
            if self.deadlock_candidate():
                stalled += 1
                if stalled >= stall_iters:
                    raise RuntimeError(self._stall_report())
            else:
                stalled = 0
                time.sleep(0.001)  # future arrival: wait, don't hot-spin
        return {rid: it for rid, it in self.items.items()}
