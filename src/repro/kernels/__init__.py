"""Pallas TPU kernels for the compute hot-spots HydraInfer optimizes:

  flash_attention  - chunked-prefill causal/windowed/GQA attention
  paged_attention  - decode attention over paged KV (scalar-prefetched
                     block tables; paper uses FlashAttention/FlashInfer)
  cache_write      - the paper's fused KV+image-cache write-block kernel
  selective_scan   - Mamba-1 recurrence (falcon-mamba / zamba2 hot loop)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jitted wrapper), ref.py (pure-jnp oracle).  Validated with
interpret=True on CPU; pass interpret=False on real TPU.
"""
