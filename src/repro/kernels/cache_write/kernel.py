"""Pallas TPU fused paged-cache write.

The paper (§4.5) fuses the many small per-token cache writes — for BOTH the
multi-layer KV cache and the single-layer image-token cache, which share a
block layout — into one kernel launch to avoid per-write launch overhead.
Here: grid over new tokens; the destination *row* of the paged cache is
selected via a scalar-prefetched slot mapping in the BlockSpec index_map,
and the cache operand is input/output-aliased so the write is in-place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _write_kernel(slots, new_ref, cache_in_ref, cache_out_ref):
    # the BlockSpec index_map already routed the cache refs to (block, row);
    # the whole block is the destination row [1, 1, w].  cache_in is aliased
    # with the output, so untouched rows pass through in place.
    cache_out_ref[0, 0] = new_ref[0].astype(cache_out_ref.dtype)


def cache_write_tpu(cache, new, slot_mapping, *, interpret: bool = False):
    """cache: [n_blocks, bs, w]; new: [T, w]; slot_mapping: [T] -> updated cache."""
    n_blocks, bs, w = cache.shape
    T = new.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, w), lambda t, slots: (t, 0)),
            pl.BlockSpec((1, 1, w),
                         lambda t, slots: (slots[t] // bs, slots[t] % bs, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w),
                               lambda t, slots: (slots[t] // bs, slots[t] % bs, 0)),
    )
    return pl.pallas_call(
        _write_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={2: 0},   # cache operand aliases the output
        interpret=interpret,
    )(slot_mapping, new, cache)
