"""Jitted wrapper for the fused cache write (KV cache AND image cache —
they share the paged block layout, so one kernel serves both)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.cache_write.kernel import cache_write_tpu
from repro.kernels.cache_write.ref import cache_write_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"),
                   donate_argnums=(0,))
def cache_write(cache, new, slot_mapping, *, interpret: bool = True,
                use_kernel: bool = True):
    if not use_kernel:
        return cache_write_ref(cache, new, slot_mapping)
    return cache_write_tpu(cache, new, slot_mapping, interpret=interpret)
