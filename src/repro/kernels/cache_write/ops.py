"""Jitted wrapper for the fused cache write (KV cache AND image cache —
they share the paged block layout, so one kernel serves both)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cache_write.kernel import cache_write_tpu
from repro.kernels.cache_write.ref import cache_write_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"),
                   donate_argnums=(0,))
def cache_write(cache, new, slot_mapping, *, interpret: bool = True,
                use_kernel: bool = True):
    if not use_kernel:
        return cache_write_ref(cache, new, slot_mapping)
    return cache_write_tpu(cache, new, slot_mapping, interpret=interpret)


def paged_token_write(data, layer: int, rows, slots, *, interpret: bool = True,
                      use_kernel: bool = True):
    """Append one token per request into every tensor of one layer of a
    ``[T, L, num_blocks, bs, width]`` paged store with ONE fused kernel
    launch (paper §4.5: batch the many small per-token cache writes).

    rows: [T, B, width] new per-tensor rows; slots: [B] within-plane row
    slots (``block * bs + offset``); ``layer`` is a static layer index.
    Returns the updated store (in place under donation/aliasing).

    Exactly the C == 1 case of :func:`paged_chunk_write`.
    """
    return paged_chunk_write(data, layer, rows[:, :, None, :], slots[:, None],
                             interpret=interpret, use_kernel=use_kernel)


def paged_chunk_write(data, layer: int, rows, slots, *, interpret: bool = True,
                      use_kernel: bool = True):
    """Append a whole prefill *chunk* per request — C tokens each — into
    every tensor of one layer of a ``[T, L, num_blocks, bs, width]`` paged
    store with ONE fused kernel launch (the multi-token extension of
    :func:`paged_token_write`).

    rows: [T, B, C, width] new per-tensor chunk rows; slots: [B, C]
    within-plane row slots (``block * bs + offset``; padded chunk positions
    point at the scratch block); ``layer`` is a static layer index.
    Returns the updated store (in place under donation/aliasing).
    """
    T, L, NB, bs, w = data.shape
    B, C = slots.shape
    flat = data.reshape(T * L * NB, bs, w)
    new = rows.reshape(T * B * C, w)
    plane = (jnp.arange(T, dtype=jnp.int32) * L + layer) * (NB * bs)
    slot_vec = (plane[:, None] + slots.reshape(-1)[None, :]).reshape(-1)
    flat = cache_write(flat, new, slot_vec, interpret=interpret,
                       use_kernel=use_kernel)
    return flat.reshape(T, L, NB, bs, w)
