"""Pure-jnp oracle for the fused paged-cache write (scatter)."""
from __future__ import annotations

import jax.numpy as jnp


def cache_write_ref(cache, new, slot_mapping):
    """cache: [n_blocks, bs, w]; new: [T, w]; slot_mapping: [T] global slots.

    Returns the cache with new[t] written at slot_mapping[t]
    (= block slot//bs, row slot%bs).
    """
    n_blocks, bs, w = cache.shape
    flat = cache.reshape(n_blocks * bs, w)
    flat = flat.at[slot_mapping].set(new.astype(cache.dtype))
    return flat.reshape(n_blocks, bs, w)
