"""Pallas TPU flash attention (prefill): causal, sliding-window, GQA.

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks) with the kv-block index
innermost; the online-softmax running state (m, l, acc) lives in VMEM
scratch and persists across the kv grid dimension.  BlockSpecs tile
HBM->VMEM: q/o blocks are (block_q, head_dim), k/v blocks (block_k,
head_dim); all matmul dims padded to the 128-lane MXU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, sm_scale: float, causal: bool,
                  window: int, n_kv_blocks: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len  # guards zero-padded keys (non-causal included)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False, kv_len: int = 0):
    """q: [B, H, Sq, D]; k/v: [B, Kh, Sk, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seq to block multiple"
    nq, nk = Sq // block_q, Sk // block_k
    kv_len = kv_len or Sk
    sm_scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, sm_scale=sm_scale,
        causal=causal, window=window, n_kv_blocks=nk, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
