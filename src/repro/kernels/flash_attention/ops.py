"""Jitted wrapper: pads sequences to block multiples, dispatches kernel/ref."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "use_kernel"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True, use_kernel: bool = True):
    """Public op.  q: [B, H, Sq, D]; k/v: [B, Kh, Sk, D].

    ``interpret=True`` executes the Pallas kernel body in Python on CPU
    (this container has no TPU); on TPU pass interpret=False.
    """
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q or pad_k:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    out = flash_attention_tpu(qp, kp, vp, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=interpret,
                              kv_len=Sk)
    return out[:, :, :Sq]
