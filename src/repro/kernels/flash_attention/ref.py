"""Pure-jnp oracle for causal (optionally sliding-window, GQA) attention."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        kv_offset: int = 0):
    """q: [B, H, Sq, D]; k/v: [B, Kh, Sk, D].  float32 math, q.dtype out."""
    B, H, Sq, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(B, Kh, G, Sq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) / math.sqrt(D)
    qpos = kv_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vf)
    return out.reshape(B, H, Sq, D).astype(q.dtype)
