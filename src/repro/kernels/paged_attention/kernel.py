"""Pallas TPU paged attention: decode and chunked prefill.

Decode: one new query token per request attends to its paged KV cache.
Chunked prefill: a chunk of C query tokens per request attends the same
pages with a *chunk-causal* mask — query c (absolute position ctx+c) sees
key positions <= ctx+c, so one kernel covers both the prior context and
the intra-chunk triangle once the chunk's K/V rows are written into the
pages (write-then-attend).

In both, the block table is a *scalar-prefetched* operand
(PrefetchScalarGridSpec) so the BlockSpec index_map can chase page
indirections at grid-issue time — the TPU-native replacement for GPU
pointer-chasing page tables.

Grid: (batch, max_pages) with per-batch online-softmax scratch persisting
across the page dimension.  KV pages are tiled HBM->VMEM one page at a
time: block (1, page_size, Kh*D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(block_tables, lengths, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page: int, n_kv_heads: int,
                  max_pages: int, window: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    H, D = q_ref.shape[1], q_ref.shape[2]
    Kh = n_kv_heads
    G = H // Kh
    q = q_ref[0].astype(jnp.float32) / math.sqrt(D)       # [H, D]
    k = k_ref[0].astype(jnp.float32)                      # [page, Kh, D]
    v = v_ref[0].astype(jnp.float32)

    # positions of this page's tokens within the request
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)[0]
    valid = pos < lengths[b]                              # [page]
    if window:  # sliding-window lower bound (static: baked per-layer)
        valid &= pos >= lengths[b] - window

    qg = q.reshape(Kh, G, D)
    s = jnp.einsum("kgd,pkd->kgp", qg, k,
                   preferred_element_type=jnp.float32)    # [Kh, G, page]
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_scr[...]                                   # [Kh, G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc = jnp.einsum("kgp,pkd->kgd", p, v,
                     preferred_element_type=jnp.float32)  # [Kh, G, D]
    acc_scr[...] = alpha[..., None] * acc_scr[...] + acc
    m_scr[...] = m_new

    @pl.when(j == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / l).reshape(H, D).astype(o_ref.dtype)


def paged_attention_tpu(q, k_pages, v_pages, block_tables, lengths, *,
                        interpret: bool = False, window: int = 0):
    """q: [B, H, D]; pages: [n_pages, page, Kh, D];
    block_tables: [B, max_pages]; lengths: [B]."""
    B, H, D = q.shape
    n_pages, page, Kh, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    kernel = functools.partial(_paged_kernel, page=page, n_kv_heads=Kh,
                               max_pages=max_pages, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, bt, ln: (b, 0, 0)),
            # page indirection: the block index comes from the prefetched table
            pl.BlockSpec((1, page, Kh, D), lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, Kh, D), lambda b, j, bt, ln: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Kh, H // Kh), jnp.float32),
            pltpu.VMEM((Kh, H // Kh), jnp.float32),
            pltpu.VMEM((Kh, H // Kh, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_pages, v_pages)


def _paged_prefill_kernel(block_tables, ctx_lens, q_ref, k_ref, v_ref, o_ref,
                          m_scr, l_scr, acc_scr, *, page: int,
                          n_kv_heads: int, max_pages: int, window: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    C, H, D = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    Kh = n_kv_heads
    G = H // Kh
    q = q_ref[0].astype(jnp.float32) / math.sqrt(D)       # [C, H, D]
    k = k_ref[0].astype(jnp.float32)                      # [page, Kh, D]
    v = v_ref[0].astype(jnp.float32)

    # chunk-causal mask: query c sits at absolute position ctx+c and sees
    # key positions <= ctx+c (page-fully-masked rows self-correct through
    # the online-softmax rescale: their junk is accumulated under
    # m == NEG_INF and zeroed by alpha once a real score arrives)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (C, page), 1)
    qpos = ctx_lens[b] + jax.lax.broadcasted_iota(jnp.int32, (C, page), 0)
    valid = pos <= qpos                                   # [C, page]
    if window:  # sliding-window lower bound (static: baked per-layer)
        valid &= pos > qpos - window

    qg = q.reshape(C, Kh, G, D)
    s = jnp.einsum("ckgd,pkd->ckgp", qg, k,
                   preferred_element_type=jnp.float32)    # [C, Kh, G, page]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m_prev = m_scr[...]                                   # [C, Kh, G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc = jnp.einsum("ckgp,pkd->ckgd", p, v,
                     preferred_element_type=jnp.float32)  # [C, Kh, G, D]
    acc_scr[...] = alpha[..., None] * acc_scr[...] + acc
    m_scr[...] = m_new

    @pl.when(j == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / l).reshape(C, H, D).astype(o_ref.dtype)


def paged_prefill_attention_tpu(q, k_pages, v_pages, block_tables, ctx_lens,
                                *, interpret: bool = False, window: int = 0):
    """q: [B, C, H, D] chunk queries (query c at position ctx_lens[b] + c);
    pages: [n_pages, page, Kh, D]; block_tables: [B, max_pages];
    ctx_lens: [B] tokens cached before the chunk."""
    B, C, H, D = q.shape
    n_pages, page, Kh, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    kernel = functools.partial(_paged_prefill_kernel, page=page,
                               n_kv_heads=Kh, max_pages=max_pages,
                               window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, C, H, D), lambda b, j, bt, cl: (b, 0, 0, 0)),
            # page indirection: the block index comes from the prefetched table
            pl.BlockSpec((1, page, Kh, D), lambda b, j, bt, cl: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, Kh, D), lambda b, j, bt, cl: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, H, D), lambda b, j, bt, cl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, Kh, H // Kh), jnp.float32),
            pltpu.VMEM((C, Kh, H // Kh), jnp.float32),
            pltpu.VMEM((C, Kh, H // Kh, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, D), q.dtype),
        interpret=interpret,
    )(block_tables, ctx_lens, q, k_pages, v_pages)
