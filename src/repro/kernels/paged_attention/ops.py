"""Jitted wrappers for paged attention (decode + chunked prefill)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import (paged_attention_tpu,
                                                  paged_prefill_attention_tpu)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_prefill_attention_ref)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "use_kernel", "window"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret: bool = True, use_kernel: bool = True,
                    window: int = 0):
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   window=window)
    return paged_attention_tpu(q, k_pages, v_pages, block_tables, lengths,
                               interpret=interpret, window=window)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "use_kernel", "window"))
def paged_prefill_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                            interpret: bool = True, use_kernel: bool = True,
                            window: int = 0):
    """Chunk queries [B, C, H, D] against pages, chunk-causal (query c sits
    at absolute position ``ctx_lens[b] + c``; the chunk's K/V rows must
    already be written into the pages)."""
    if not use_kernel:
        return paged_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                           ctx_lens, window=window)
    return paged_prefill_attention_tpu(q, k_pages, v_pages, block_tables,
                                       ctx_lens, interpret=interpret,
                                       window=window)
