"""Jitted wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention_tpu
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit,
                   static_argnames=("interpret", "use_kernel", "window"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret: bool = True, use_kernel: bool = True,
                    window: int = 0):
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   window=window)
    return paged_attention_tpu(q, k_pages, v_pages, block_tables, lengths,
                               interpret=interpret, window=window)
