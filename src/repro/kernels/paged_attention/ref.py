"""Pure-jnp oracles for paged attention: decode (one query token) and
chunked prefill (a chunk of queries, chunk-causal over pages)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        window: int = 0):
    """q: [B, H, D]; pages: [n_pages, page, Kh, D];
    block_tables: [B, max_pages] int32; lengths: [B] (tokens valid).

    ``window`` > 0: sliding-window layers only see the last ``window``
    positions (the query sits at position lengths-1).
    """
    B, H, D = q.shape
    n_pages, page, Kh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = H // Kh
    S = max_pages * page
    # gather each request's pages into a contiguous [B, S, Kh, D]
    k = k_pages[block_tables].reshape(B, S, Kh, D)
    v = v_pages[block_tables].reshape(B, S, Kh, D)
    qf = q.astype(jnp.float32).reshape(B, Kh, G, D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32))
    scores /= math.sqrt(D)
    valid = jnp.arange(S)[None] < lengths[:, None]
    if window:
        valid &= jnp.arange(S)[None] >= (lengths[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                                *, window: int = 0):
    """Chunked-prefill attention over pages.  q: [B, C, H, D] — query c of
    request b sits at absolute position ``ctx_lens[b] + c``; pages:
    [n_pages, page, Kh, D]; block_tables: [B, max_pages] int32; ctx_lens:
    [B] tokens already cached *before* this chunk.

    The chunk's own K/V rows must already be written into the pages
    (write-then-attend, like the decode path), so chunk-causality is pure
    masking: query c sees key positions ``<= ctx_lens[b] + c`` — the prior
    context plus the chunk prefix up to and including itself — restricted
    to the last ``window`` positions when ``window`` > 0.  Rows whose mask
    is empty (padded lanes / padded chunk positions) produce finite garbage
    the caller discards.
    """
    B, C, H, D = q.shape
    n_pages, page, Kh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = H // Kh
    S = max_pages * page
    k = k_pages[block_tables].reshape(B, S, Kh, D)
    v = v_pages[block_tables].reshape(B, S, Kh, D)
    qf = q.astype(jnp.float32).reshape(B, C, Kh, G, D)
    scores = jnp.einsum("bckgd,bskd->bkgcs", qf, k.astype(jnp.float32))
    scores /= math.sqrt(D)
    qpos = ctx_lens[:, None] + jnp.arange(C)                     # [B, C]
    valid = jnp.arange(S)[None, None, :] <= qpos[:, :, None]     # [B, C, S]
    if window:
        valid &= jnp.arange(S)[None, None, :] > qpos[:, :, None] - window
    scores = jnp.where(valid[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskd->bckgd", probs, v.astype(jnp.float32))
    return out.reshape(B, C, H, D).astype(q.dtype)
