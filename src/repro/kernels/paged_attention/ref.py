"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        window: int = 0):
    """q: [B, H, D]; pages: [n_pages, page, Kh, D];
    block_tables: [B, max_pages] int32; lengths: [B] (tokens valid).

    ``window`` > 0: sliding-window layers only see the last ``window``
    positions (the query sits at position lengths-1).
    """
    B, H, D = q.shape
    n_pages, page, Kh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = H // Kh
    S = max_pages * page
    # gather each request's pages into a contiguous [B, S, Kh, D]
    k = k_pages[block_tables].reshape(B, S, Kh, D)
    v = v_pages[block_tables].reshape(B, S, Kh, D)
    qf = q.astype(jnp.float32).reshape(B, Kh, G, D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32))
    scores /= math.sqrt(D)
    valid = jnp.arange(S)[None] < lengths[:, None]
    if window:
        valid &= jnp.arange(S)[None] >= (lengths[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
