"""Pallas TPU selective scan (Mamba-1 recurrence).

TPU adaptation of the GPU selective-scan: instead of one thread block
holding the state in registers/shared memory, each grid cell owns a
``block_d`` slice of d_inner (the recurrence is elementwise in d_inner, so
this is embarrassingly parallel across the VPU lanes) and keeps the running
state [block_d, N] in VMEM scratch.  The grid's innermost dimension walks
sequence chunks so the scratch state persists chunk-to-chunk; within a
chunk the recurrence steps with a fori_loop over VMEM-resident tiles.

Grid: (batch, d_blocks, s_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dt_ref, x_ref, A_ref, B_ref, C_ref, h0_ref, y_ref, hout_ref,
                 h_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)        # [bd, N]

    A = A_ref[...].astype(jnp.float32)                    # [bd, N]

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)           # [bd]
        x_t = x_ref[0, t].astype(jnp.float32)             # [bd]
        B_t = B_ref[0, t].astype(jnp.float32)             # [N]
        C_t = C_ref[0, t].astype(jnp.float32)             # [N]
        dA = jnp.exp(dt_t[:, None] * A)
        h = dA * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y_ref[0, t] = (h * C_t[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan_tpu(dt, x, A, Bmat, Cmat, h0, *, block_d: int = 256,
                       chunk: int = 256, interpret: bool = False):
    """dt/x: [B, S, d]; A: [d, N]; Bmat/Cmat: [B, S, N]; h0: [B, d, N].

    Returns (y [B, S, d] float32, h_final [B, d, N] float32).
    """
    Bsz, S, d = x.shape
    N = A.shape[1]
    block_d = min(block_d, d)
    chunk = min(chunk, S)
    assert d % block_d == 0 and S % chunk == 0
    nd, nc = d // block_d, S // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=nc)
    y, hout = pl.pallas_call(
        kernel,
        grid=(Bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, chunk, block_d), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((block_d, N), lambda b, i, c: (i, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, block_d, N), lambda b, i, c: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, block_d, N), lambda b, i, c: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, d), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, d, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, A, Bmat, Cmat, h0)
    return y, hout
