"""Jitted wrapper for the selective scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.selective_scan.kernel import selective_scan_tpu
from repro.kernels.selective_scan.ref import selective_scan_ref


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret",
                                             "use_kernel"))
def selective_scan(dt, x, A, Bmat, Cmat, h0=None, *, block_d: int = 256,
                   chunk: int = 256, interpret: bool = True,
                   use_kernel: bool = True):
    if h0 is None:
        Bsz, _, d = x.shape
        h0 = jnp.zeros((Bsz, d, A.shape[1]), jnp.float32)
    if not use_kernel:
        return selective_scan_ref(dt, x, A, Bmat, Cmat, h0)
    return selective_scan_tpu(dt, x, A, Bmat, Cmat, h0, block_d=block_d,
                              chunk=chunk, interpret=interpret)
