"""Pure-jnp oracle for the Mamba-1 selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dt, x, A, Bmat, Cmat, h0=None):
    """Sequential recurrence  h_t = exp(dt_t*A)*h_{t-1} + (dt_t*x_t) B_t,
    y_t = h_t . C_t.

    dt/x: [B, S, d]; A: [d, N]; Bmat/Cmat: [B, S, N]; h0: [B, d, N] or None.
    Returns (y [B, S, d] float32, h_final [B, d, N] float32).
    """
    Bsz, S, d = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, d, N), jnp.float32)

    def step(h, inp):
        dt_t, x_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None] * A)                  # [B, d, N]
        h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    seq = (jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
           jnp.moveaxis(x.astype(jnp.float32), 1, 0),
           jnp.moveaxis(Bmat.astype(jnp.float32), 1, 0),
           jnp.moveaxis(Cmat.astype(jnp.float32), 1, 0))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), seq)
    return jnp.moveaxis(ys, 0, 1), h
