import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).  Do not move them.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, record memory/cost analysis + collective bytes.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all          # orchestrates subprocesses
  python -m repro.launch.dryrun --all --multi-pod

Each combo runs in its own subprocess under --all (jax state isolation and
hang containment); results land in experiments/dryrun/*.json.
"""
import argparse
import functools
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES,
                           get_config, input_specs, shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import sharding as SH
from repro.train.optim import AdamWConfig
from repro.train.train import train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the (per-device) HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT )?%?[\w.\-]+ = (.*?) (\w[\w\-]*)\(", s)
        if not m:
            continue
        op = m.group(2)
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                for dt, dims in _SHAPE_RE.findall(m.group(1)):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    out[c] += n * _DTYPE_BYTES[dt]
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
def _batch_pspec(spec_tree, mesh):
    """Shardings for the input batch dict (batch dim only if it divides)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_sz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    out = {}
    for k, v in spec_tree.items():
        b = dp if (v.ndim >= 1 and v.shape[0] % dp_sz == 0) else None
        if k in ("tokens", "labels", "token"):
            out[k] = NamedSharding(mesh, P(b, None))
        elif k in ("media", "frames"):
            out[k] = NamedSharding(mesh, P(b, None, None))
        else:  # cache_len scalar
            out[k] = NamedSharding(mesh, P())
    return out


def _cache_shardings(cfg, cache_spec, mesh, layout="kvdim"):
    pspecs = M.cache_pspecs(cfg, layout)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def fix(leaf, spec):
        names = set(mesh.axis_names)
        out = []
        for d, s in enumerate(spec):
            s = dp if s == "dp" else s
            if s is None:
                out.append(None)
                continue
            if isinstance(s, str):
                s = (s,)
            s = tuple(a for a in s if a in names)
            sz = int(np.prod([mesh.shape[a] for a in s])) if s else 1
            out.append(s if s and leaf.shape[d] % sz == 0 else None)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, cache_spec, pspecs,
                        is_leaf=lambda n: not isinstance(n, (dict, list)))


def build_lowering(arch: str, shape_name: str, multi_pod: bool,
                   kv_layout: str = "kvdim", moe_dispatch: str = "base"):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, why
    mesh = make_production_mesh(multi_pod=multi_pod)
    SH.set_mesh(mesh)
    if moe_dispatch == "sharded":
        from repro.models import moe as _moe
        _moe.DATA_SHARDED_DISPATCH = True
    elif moe_dispatch == "shardmap":
        from repro.models import moe as _moe
        _moe.MOE_SHARDMAP = True
    dtype = jnp.bfloat16
    pspec = M.param_specs(cfg, dtype)
    # ZeRO-style extra sharding: always for train (optimizer state), and for
    # inference when model-parallel sharding alone exceeds ~60% of HBM
    # (DeepSeek-V2-236B: 472 GB bf16 / 16-way TP = 29.5 GB >> 16 GB v5e).
    from repro.core.costmodel import param_count
    tp = mesh.shape["model"]
    param_gb = param_count(cfg) * 2 / tp / 1e9
    fsdp = shape.kind == "train"
    # huge-MoE inference: 2D expert tensor-parallelism instead of ZeRO
    # gathers (EXPERIMENTS.md #Perf, deepseek decode iteration 2)
    expert_2d = shape.kind != "train" and param_gb > 9.6 and cfg.num_experts > 0
    pshard = SH.param_shardings(mesh, pspec, fsdp=fsdp, expert_2d=expert_2d)
    specs = input_specs(cfg, shape)
    bshard = _batch_pspec(specs, mesh)

    if shape.kind == "train":
        opt = AdamWConfig()
        f32 = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
        opt_spec = {"step": jax.ShapeDtypeStruct((), jnp.int32),
                    "m": f32(pspec), "v": f32(pspec)}
        opt_shard = {"step": NamedSharding(mesh, P()),
                     "m": pshard, "v": pshard}

        def step(params, opt_state, batch):
            return train_step(cfg, opt, params, opt_state, batch, remat=True)

        fn = jax.jit(step, in_shardings=(pshard, opt_shard, bshard),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pspec, opt_spec, specs)
    elif shape.kind == "prefill":
        def step(params, batch):
            return M.prefill(cfg, params, batch["tokens"],
                             media=batch.get("media"),
                             frames=batch.get("frames"))

        fn = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = fn.lower(pspec, specs)
    else:  # decode
        cache_spec = M.cache_specs(cfg, shape.global_batch, shape.seq_len,
                                   dtype)
        cshard = _cache_shardings(cfg, cache_spec, mesh, kv_layout)

        def step(params, cache, batch):
            return M.decode_step(cfg, params, cache, batch["cache_len"],
                                 batch["token"])

        fn = jax.jit(step, in_shardings=(pshard, cshard, bshard),
                     donate_argnums=(1,))
        lowered = fn.lower(pspec, cache_spec, specs)
    return (cfg, mesh, lowered), ""


def run_one(arch: str, shape_name: str, multi_pod: bool,
            kv_layout: str = "kvdim", tag: str = "",
            moe_dispatch: str = "base") -> dict:
    t0 = time.time()
    built, why = build_lowering(arch, shape_name, multi_pod, kv_layout,
                                moe_dispatch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if built is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    cfg, mesh, lowered = built
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1
    # collectives only exist post-GSPMD-partitioning: parse the compiled
    # (per-device) HLO module, not the pre-partition StableHLO
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    n_dev = mesh.size
    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    print(json.dumps(res, indent=1))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    fn = os.path.join(RESULTS_DIR, f"{arch}_{shape_name}_{mesh_name}{tag}.json")
    with open(fn, "w") as f:
        json.dump(res, f, indent=1)
    return res


def run_all(multi_pod: bool, archs=None, timeout: int = 3600):
    archs = archs or ASSIGNED_ARCHS
    statuses = {}
    for arch in archs:
        for shape_name in INPUT_SHAPES:
            key = f"{arch} x {shape_name}"
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name]
            if multi_pod:
                cmd.append("--multi-pod")
            t0 = time.time()
            try:
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=timeout)
                ok = r.returncode == 0
                tail = (r.stdout + r.stderr).strip().splitlines()[-3:]
                statuses[key] = "ok" if ok else f"FAIL: {' | '.join(tail)}"
            except subprocess.TimeoutExpired:
                statuses[key] = "TIMEOUT"
            print(f"{key:45s} {statuses[key][:120]}  ({time.time()-t0:.0f}s)",
                  flush=True)
    n_bad = sum(1 for v in statuses.values() if v not in ("ok",)
                and not v.startswith("skip"))
    print(f"\n{len(statuses)} combos, {n_bad} failures")
    return statuses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv-layout", default="kvdim", choices=["kvdim", "seq"])
    ap.add_argument("--moe-dispatch", default="base", choices=["base", "sharded", "shardmap"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    if args.all:
        run_all(args.multi_pod)
        return
    res = run_one(args.arch, args.shape, args.multi_pod,
                  kv_layout=args.kv_layout, tag=args.tag,
                  moe_dispatch=args.moe_dispatch)
    if res["status"] == "skipped":
        print(f"SKIPPED: {res['reason']}")


if __name__ == "__main__":
    main()
