"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — callers decide when devices are materialized.
Production target: TPU v5e, 256 chips/pod (16x16), 2 pods for multi-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests / CPU smoke)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
