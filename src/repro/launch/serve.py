"""Serving driver: real-execution HydraInfer cluster on a reduced model,
simulator-backed paper-scale runs, or an OpenAI-style HTTP front.

Real:  PYTHONPATH=src python -m repro.launch.serve --arch llava-1.5-7b \
           --disagg E1,P1,D1 --requests 8
Sim:   PYTHONPATH=src python -m repro.launch.serve --sim --arch llava-next-7b \
           --dataset textcaps --rate 16 --n 200
HTTP:  PYTHONPATH=src python -m repro.launch.serve --http --port 8000
       curl localhost:8000/v1/chat/completions -d '{"messages": [...],
           "stream": true, "temperature": 0.7}'

The HTTP front (DESIGN.md §13) speaks ``/v1/chat/completions`` with SSE
streaming and image inputs over the streaming ``Engine`` — stdlib only.
There is no real tokenizer in this repro (models run on random weights):
text maps to stable per-word hash token ids and generated ids render as
``<id>`` placeholders; an ``image_url`` part maps to a deterministic
pseudo-embedding seeded by the URL hash, standing in for a real vision
tower's patch embeddings.
"""
from __future__ import annotations

import argparse
import json
import re
import time
import zlib

import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core.simulator import ROLE_SETS, DisaggConfig, RoleSpec


def parse_disagg(s: str) -> DisaggConfig:
    """Parse ``E1,P3,D4`` — optionally with per-role hardware overrides for
    heterogeneous clusters (DESIGN.md §7.2), e.g. ``E1@l40s,P3,D4@h800``."""
    from repro.core.costmodel import HARDWARE

    merged: dict = {}   # role -> [count, hw | None]
    for part in s.split(","):
        m = re.fullmatch(r"(?:([A-Z]+)(\d+)|(\d+)([A-Z]+))(?:@(\w+))?",
                         part.strip())
        if not m:
            raise ValueError(f"bad disagg part {part!r} "
                             f"(e.g. E1,P3,D4 or E1@l40s,PD7@h800)")
        role = m.group(1) or m.group(4)
        if role not in ROLE_SETS:
            raise ValueError(f"unknown role {role!r}; "
                             f"known: {sorted(ROLE_SETS)}")
        n = int(m.group(2) or m.group(3))
        hw_name = m.group(5)
        hw = None
        if hw_name is not None:
            if hw_name.lower() not in HARDWARE:
                raise ValueError(f"unknown hardware {hw_name!r}; "
                                 f"known: {sorted(HARDWARE)}")
            hw = HARDWARE[hw_name.lower()]
        if role in merged:
            # a role group runs on one hardware profile; a repeated role
            # must name the same hardware (or none) regardless of order
            if merged[role][1] is not hw:
                raise ValueError(f"conflicting hardware for role {role!r}")
            merged[role][0] += n
        else:
            merged[role] = [n, hw]
    return DisaggConfig({role: n if hw is None else RoleSpec(count=n, hw=hw)
                         for role, (n, hw) in merged.items()})


def _fault_kwargs(args) -> dict:
    """Fault-tolerance knobs shared by the real and HTTP drivers
    (DESIGN.md §15): ``--fault crash@100:1,stall@40:0+5`` injects a
    deterministic fault plan, ``--shed deadline`` turns on deadline-aware
    load shedding."""
    from repro.engine.faults import FaultPlan

    kw = {}
    if getattr(args, "fault", None):
        kw["fault_plan"] = FaultPlan.parse(args.fault)
    if getattr(args, "shed", None):
        kw["shed_policy"] = args.shed
    return kw


def run_real(args):
    import jax
    from repro.engine.server import HydraServer
    from repro.models import model as M

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = HydraServer(cfg, params, parse_disagg(args.disagg),
                         policy=args.policy, **_fault_kwargs(args))
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        media = None
        if cfg.frontend != "none" and i % 2 == 0:
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        rids.append(server.submit(prompt, media=media,
                                  max_new_tokens=args.max_new_tokens))
    out = server.run()
    for rid in rids:
        print(f"req {rid}: {out[rid].generated}")
    print(f"{len(rids)} requests in {time.time()-t0:.1f}s, "
          f"{server.n_migrations} migrations "
          f"({server.migrated_bytes/1e6:.1f} MB)")
    if args.fault or args.shed:
        fs = server.fault_stats()
        print(f"faults: {fs['replays']} replays, {fs['shed']} shed, "
              f"{fs['transfer_retries']} transfer retries, "
              f"dead instances {fs['dead_instances']}")


# ---------------------------------------------------------------------------
# OpenAI-style HTTP front (DESIGN.md §13)
# ---------------------------------------------------------------------------
class UnknownModelError(ValueError):
    """Request names a model this server does not serve (-> HTTP 404)."""


def encode_text(text: str, vocab: int) -> np.ndarray:
    """Demo tokenizer: stable per-word hash ids (no real vocab in the repro)."""
    toks = [zlib.crc32(w.encode()) % vocab for w in text.split()]
    return np.asarray(toks or [0], np.int32)


def media_from_url(url: str, cfg) -> np.ndarray:
    """Deterministic pseudo patch-embedding for an image reference."""
    rng = np.random.default_rng(zlib.crc32(url.encode()) & 0xFFFFFFFF)
    return (rng.standard_normal((cfg.media_tokens, cfg.d_model))
            * 0.1).astype(np.float32)


# request-hardening limits (DESIGN.md §15): every violation maps to a JSON
# 4xx, never a dead handler thread
MAX_IMAGES = 16            # images per request
MAX_PROMPT_TOKENS = 8192   # post-tokenization prompt length
MAX_COMPLETION_TOKENS = 2048


def parse_chat_request(body: dict, cfg):
    """``/v1/chat/completions`` body -> (prompt tokens, media list | None,
    SamplingParams, stream flag).  Raises ValueError on malformed input."""
    from repro.core.request import SamplingParams

    if not isinstance(body, dict):
        raise ValueError("request body must be a JSON object")
    model = body.get("model")
    if model is not None and model != cfg.name:
        raise UnknownModelError(
            f"model {model!r} not found (serving {cfg.name!r})")
    msgs = body.get("messages")
    if not isinstance(msgs, list) or not msgs:
        raise ValueError("messages must be a non-empty list")
    words, media = [], []
    for m in msgs:
        if not isinstance(m, dict):
            raise ValueError("each message must be an object")
        content = m.get("content", "")
        if isinstance(content, str):
            words.append(content)
            continue
        if not isinstance(content, list):
            raise ValueError("message content must be a string or parts list")
        for part in content:
            if not isinstance(part, dict):
                raise ValueError("each content part must be an object")
            kind = part.get("type")
            if kind == "text":
                words.append(part.get("text", ""))
            elif kind == "image_url":
                url = part.get("image_url")
                url = url.get("url", "") if isinstance(url, dict) else str(url)
                if len(media) >= MAX_IMAGES:
                    raise ValueError(
                        f"too many images (limit {MAX_IMAGES})")
                media.append(media_from_url(url, cfg))
            else:
                raise ValueError(f"unsupported content part {kind!r}")
    stop: list = []
    raw_stop = body.get("stop") or []
    if isinstance(raw_stop, str):
        raw_stop = [raw_stop]
    for s in raw_stop:
        stop.extend(int(t) for t in encode_text(str(s), cfg.vocab_size))
    stop.extend(int(t) for t in body.get("stop_token_ids", []))
    max_tokens = int(body.get("max_tokens", 16))
    if not 1 <= max_tokens <= MAX_COMPLETION_TOKENS:
        raise ValueError(f"max_tokens must be in "
                         f"[1, {MAX_COMPLETION_TOKENS}], got {max_tokens}")
    sampling = SamplingParams(
        temperature=float(body.get("temperature", 0.0)),
        top_k=int(body.get("top_k", 0)),
        top_p=float(body.get("top_p", 1.0)),
        seed=(None if body.get("seed") is None else int(body["seed"])),
        stop=tuple(stop),
        max_tokens=max_tokens)
    prompt = encode_text(" ".join(words), cfg.vocab_size)
    if len(prompt) > MAX_PROMPT_TOKENS:
        raise ValueError(f"prompt too long: {len(prompt)} tokens "
                         f"(limit {MAX_PROMPT_TOKENS})")
    return prompt, (media or None), sampling, bool(body.get("stream", False))


def token_piece(tok: int) -> str:
    return f"<{tok}>"


def make_handler(engine, cfg):
    """Build the request-handler class bound to one live engine."""
    from http.server import BaseHTTPRequestHandler

    from repro.engine.faults import AdmissionError

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, *a):  # quiet by default (tests spin servers)
            pass

        def handle(self):
            try:
                super().handle()
            except (ConnectionResetError, BrokenPipeError):
                pass  # client dropped a kept-alive connection: not an error

        def _json(self, code: int, obj: dict):
            payload = json.dumps(obj).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload)

        def do_GET(self):
            if self.path == "/v1/models":
                self._json(200, {"object": "list", "data": [
                    {"id": cfg.name, "object": "model",
                     "owned_by": "hydrainfer-repro"}]})
            elif self.path == "/healthz":
                self._json(200, {"status": "ok"})
            else:
                self._json(404, {"error": {"message": "not found"}})

        def do_POST(self):
            if self.path != "/v1/chat/completions":
                self._json(404, {"error": {"message": "not found"}})
                return
            try:
                n = int(self.headers.get("Content-Length", 0))
                body = json.loads(self.rfile.read(n) or b"{}")
                prompt, media, sampling, stream = \
                    parse_chat_request(body, cfg)
            except UnknownModelError as e:
                self._json(404, {"error": {"message": str(e),
                                           "type": "model_not_found"}})
                return
            except (ValueError, KeyError, TypeError, AttributeError,
                    json.JSONDecodeError) as e:
                self._json(400, {"error": {"message": str(e),
                                           "type": "invalid_request_error"}})
                return
            try:
                rid = engine.submit(prompt, media=media, sampling=sampling)
            except AdmissionError as e:
                # deadline-aware shedding rejected the submit: capacity is
                # durably degraded (DESIGN.md §15)
                self._json(503, {"error": {"message": str(e),
                                           "type": "overloaded_error"}})
                return
            try:
                if stream:
                    self._stream(rid, len(prompt))
                else:
                    self._complete(rid, len(prompt))
            except (BrokenPipeError, ConnectionResetError):
                raise               # handled by handle(): client went away
            except Exception as e:  # engine fault: report, don't kill the
                engine.abort(rid)   # handler thread (connection reusable)
                engine.release(rid)
                self._json(500, {"error": {"message": str(e),
                                           "type": "internal_error"}})

        # -- one-shot response ------------------------------------------
        def _complete(self, rid: int, n_prompt: int):
            reason = "length"
            for ev in engine.events(rid):
                if ev.kind == "finish":
                    reason = ev.finish_reason
            toks = engine.result(rid).generated
            engine.release(rid)  # bound memory across the request stream
            self._json(200, {
                "id": f"chatcmpl-{rid}",
                "object": "chat.completion",
                "created": int(time.time()),
                "model": cfg.name,
                "choices": [{
                    "index": 0,
                    "message": {"role": "assistant",
                                "content": "".join(token_piece(t)
                                                   for t in toks)},
                    "finish_reason": reason}],
                "usage": {"prompt_tokens": n_prompt,
                          "completion_tokens": len(toks),
                          "total_tokens": n_prompt + len(toks)}})

        # -- SSE streaming ----------------------------------------------
        def _sse(self, obj):
            self.wfile.write(b"data: " + json.dumps(obj).encode() + b"\n\n")
            self.wfile.flush()

        def _stream(self, rid: int, n_prompt: int):
            self.send_response(200)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-cache")
            self.send_header("Connection", "close")
            self.end_headers()
            base = {"id": f"chatcmpl-{rid}",
                    "object": "chat.completion.chunk",
                    "created": int(time.time()), "model": cfg.name}
            try:
                for ev in engine.events(rid):
                    if ev.kind == "finish":
                        self._sse({**base, "choices": [
                            {"index": 0, "delta": {},
                             "finish_reason": ev.finish_reason}]})
                    else:
                        delta = {"content": token_piece(ev.token)}
                        if ev.kind == "first_token":
                            delta["role"] = "assistant"
                        self._sse({**base, "choices": [
                            {"index": 0, "delta": delta,
                             "finish_reason": None}]})
                self.wfile.write(b"data: [DONE]\n\n")
                self.wfile.flush()
            except (BrokenPipeError, ConnectionResetError):
                # client went away mid-stream: cancel the request so its
                # KV/image blocks free immediately
                engine.abort(rid)
            except Exception as e:
                # engine fault mid-stream: the 200 + SSE headers are gone,
                # so report through an SSE ``error`` event and end the
                # stream instead of killing the handler thread
                engine.abort(rid)
                try:
                    self._sse({"error": {"message": str(e),
                                         "type": "internal_error"}})
                    self.wfile.write(b"data: [DONE]\n\n")
                    self.wfile.flush()
                except (BrokenPipeError, ConnectionResetError):
                    pass
            finally:
                engine.release(rid)  # bound memory across the stream

    return Handler


def run_http(args):
    import jax
    from http.server import ThreadingHTTPServer

    from repro.engine.api import Engine
    from repro.models import model as M

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, parse_disagg(args.disagg),
                    policy=args.policy, **_fault_kwargs(args)).start()
    httpd = ThreadingHTTPServer((args.host, args.port),
                                make_handler(engine, cfg))
    print(f"serving {cfg.name} [{args.disagg}] on "
          f"http://{args.host or 'localhost'}:{httpd.server_address[1]}"
          f"/v1/chat/completions")
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        httpd.server_close()
        engine.close(drain_timeout=args.drain_timeout)


def run_sim(args):
    from repro.core.costmodel import HARDWARE
    from repro.core.metrics import summarize
    from repro.core.simulator import Cluster, Simulator
    from repro.data.workload import (IMAGE_TOKENS, PROFILES, make_requests,
                                     slo_for)

    cfg = get_config(args.arch)
    hw = HARDWARE[args.hw]
    slo = slo_for(args.arch, args.dataset)
    img = IMAGE_TOKENS.get(args.arch, cfg.media_tokens)
    reqs = make_requests(PROFILES[args.dataset], rate=args.rate, n=args.n,
                         image_tokens_per_image=img, slo=slo, seed=0)
    cl = Cluster(cfg, hw, parse_disagg(args.disagg), slo,
                 policy_name=args.policy)
    done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 300)
    s = summarize(done, args.rate, reqs[-1].arrival)
    print(f"rate={args.rate} attainment={s.attainment:.2%} "
          f"p90_ttft={s.p90_ttft:.3f}s p90_tpot={s.p90_tpot*1e3:.1f}ms "
          f"tok/s={s.tokens_per_s:.0f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-1.5-7b", choices=ALL_ARCHS)
    ap.add_argument("--disagg", default="E1,P1,D1")
    ap.add_argument("--policy", default="hydra")
    ap.add_argument("--sim", action="store_true")
    ap.add_argument("--http", action="store_true",
                    help="OpenAI-style /v1/chat/completions front")
    ap.add_argument("--host", default="")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--dataset", default="textcaps")
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--hw", default="h800")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--fault", default="",
                    help="inject faults: kind@iteration[:iid][+arg],... "
                         "(kinds: crash stall alloc drop corrupt), e.g. "
                         "crash@100:1,stall@40:0+5")
    ap.add_argument("--shed", default="", choices=["", "off", "deadline"],
                    help="load shedding policy under degraded capacity")
    ap.add_argument("--drain-timeout", type=float, default=5.0,
                    help="graceful-shutdown drain window in seconds "
                         "(HTTP front)")
    args = ap.parse_args()
    (run_http if args.http else run_sim if args.sim else run_real)(args)


if __name__ == "__main__":
    main()
