"""Serving driver: real-execution HydraInfer cluster on a reduced model,
or simulator-backed paper-scale runs.

Real:  PYTHONPATH=src python -m repro.launch.serve --arch llava-1.5-7b \
           --disagg E1,P1,D1 --requests 8
Sim:   PYTHONPATH=src python -m repro.launch.serve --sim --arch llava-next-7b \
           --dataset textcaps --rate 16 --n 200
"""
from __future__ import annotations

import argparse
import re
import time

import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core.simulator import ROLE_SETS, DisaggConfig, RoleSpec


def parse_disagg(s: str) -> DisaggConfig:
    """Parse ``E1,P3,D4`` — optionally with per-role hardware overrides for
    heterogeneous clusters (DESIGN.md §7.2), e.g. ``E1@l40s,P3,D4@h800``."""
    from repro.core.costmodel import HARDWARE

    merged: dict = {}   # role -> [count, hw | None]
    for part in s.split(","):
        m = re.fullmatch(r"(?:([A-Z]+)(\d+)|(\d+)([A-Z]+))(?:@(\w+))?",
                         part.strip())
        if not m:
            raise ValueError(f"bad disagg part {part!r} "
                             f"(e.g. E1,P3,D4 or E1@l40s,PD7@h800)")
        role = m.group(1) or m.group(4)
        if role not in ROLE_SETS:
            raise ValueError(f"unknown role {role!r}; "
                             f"known: {sorted(ROLE_SETS)}")
        n = int(m.group(2) or m.group(3))
        hw_name = m.group(5)
        hw = None
        if hw_name is not None:
            if hw_name.lower() not in HARDWARE:
                raise ValueError(f"unknown hardware {hw_name!r}; "
                                 f"known: {sorted(HARDWARE)}")
            hw = HARDWARE[hw_name.lower()]
        if role in merged:
            # a role group runs on one hardware profile; a repeated role
            # must name the same hardware (or none) regardless of order
            if merged[role][1] is not hw:
                raise ValueError(f"conflicting hardware for role {role!r}")
            merged[role][0] += n
        else:
            merged[role] = [n, hw]
    return DisaggConfig({role: n if hw is None else RoleSpec(count=n, hw=hw)
                         for role, (n, hw) in merged.items()})


def run_real(args):
    import jax
    from repro.engine.server import HydraServer
    from repro.models import model as M

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    server = HydraServer(cfg, params, parse_disagg(args.disagg),
                         policy=args.policy)
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        media = None
        if cfg.frontend != "none" and i % 2 == 0:
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        rids.append(server.submit(prompt, media=media,
                                  max_new_tokens=args.max_new_tokens))
    out = server.run()
    for rid in rids:
        print(f"req {rid}: {out[rid].generated}")
    print(f"{len(rids)} requests in {time.time()-t0:.1f}s, "
          f"{server.n_migrations} migrations "
          f"({server.migrated_bytes/1e6:.1f} MB)")


def run_sim(args):
    from repro.core.costmodel import HARDWARE
    from repro.core.metrics import summarize
    from repro.core.simulator import Cluster, Simulator
    from repro.data.workload import (IMAGE_TOKENS, PROFILES, make_requests,
                                     slo_for)

    cfg = get_config(args.arch)
    hw = HARDWARE[args.hw]
    slo = slo_for(args.arch, args.dataset)
    img = IMAGE_TOKENS.get(args.arch, cfg.media_tokens)
    reqs = make_requests(PROFILES[args.dataset], rate=args.rate, n=args.n,
                         image_tokens_per_image=img, slo=slo, seed=0)
    cl = Cluster(cfg, hw, parse_disagg(args.disagg), slo,
                 policy_name=args.policy)
    done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 300)
    s = summarize(done, args.rate, reqs[-1].arrival)
    print(f"rate={args.rate} attainment={s.attainment:.2%} "
          f"p90_ttft={s.p90_ttft:.3f}s p90_tpot={s.p90_tpot*1e3:.1f}ms "
          f"tok/s={s.tokens_per_s:.0f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llava-1.5-7b", choices=ALL_ARCHS)
    ap.add_argument("--disagg", default="E1,P1,D1")
    ap.add_argument("--policy", default="hydra")
    ap.add_argument("--sim", action="store_true")
    ap.add_argument("--dataset", default="textcaps")
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--hw", default="h800")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()
    (run_sim if args.sim else run_real)(args)


if __name__ == "__main__":
    main()
