"""Shared layer primitives: norms, RoPE, blockwise attention, MLPs.

Attention is computed blockwise over query chunks (pure-JAX flash) so long
prefills never materialize the full S x S score matrix.  KV caches are kept
flattened as ``[B, S, kv_heads*head_dim]`` so the last dim shards over the
"model" mesh axis even when kv_heads < mesh_model_size.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain

Q_CHUNK = 512  # query-block size for blockwise attention

# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


def act_fn(name: str):
    if name.startswith("gelu"):
        return functools.partial(jax.nn.gelu, approximate=True)
    return jax.nn.silu


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """Rotate-half RoPE.  x: [..., S, H, D]; positions: [..., S] or [S]."""
    d = x.shape[-1]
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int, dtype=jnp.float32):
    """Absolute sinusoidal embeddings (whisper-style).  positions: [S]."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention over a full sequence
# ---------------------------------------------------------------------------
def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        kv_offset: int = 0, q_chunk: int = Q_CHUNK):
    """q: [B, Sq, H, D]; k/v: [B, Sk, Kh, D].  GQA via head repetition.

    ``kv_offset``: absolute position of q[0] minus position of k[0]
    (chunked prefill attends to a cache prefix).  ``window`` > 0 restricts
    attention to the last ``window`` keys (sliding-window local layers) —
    implemented with a dynamic KV slice so compute scales with the window,
    not the full sequence.
    """
    B, Sq, H, D = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    scale = 1.0 / math.sqrt(D)
    q = (q * scale).astype(q.dtype)
    q_chunk = min(q_chunk, Sq)
    n_chunks = max(1, Sq // q_chunk)
    rem = Sq - n_chunks * q_chunk  # handled by padding below if nonzero
    if rem:
        pad = q_chunk - rem
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        n_chunks += 1
    qc = q.reshape(B, n_chunks, q_chunk, H, D)

    if window and Sk > window + q_chunk:
        # local attention: per q-chunk, slice [q_end - window - q_chunk, q_end)
        span = window + q_chunk

        def chunk_fn(i):
            q_i = qc[:, i]  # [B, c, H, D]
            q_start = i * q_chunk
            lo = jnp.clip(q_start + kv_offset + q_chunk - span, 0, Sk - span)
            k_i = jax.lax.dynamic_slice_in_dim(k, lo, span, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, lo, span, axis=1)
            qpos = q_start + kv_offset + jnp.arange(q_chunk)
            kpos = lo + jnp.arange(span)
            mask = kpos[None, :] <= qpos[:, None]
            mask &= kpos[None, :] > qpos[:, None] - window
            return _attend(q_i, k_i, v_i, mask, G)

        out = jax.lax.map(chunk_fn, jnp.arange(n_chunks))  # [n, B, c, H, D]
    else:
        def chunk_fn(i):
            q_i = qc[:, i]
            qpos = i * q_chunk + kv_offset + jnp.arange(q_chunk)
            kpos = jnp.arange(Sk)
            mask = jnp.ones((q_chunk, Sk), bool)
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
            return _attend(q_i, k_i=k, v_i=v, mask=mask, G=G)

        out = jax.lax.map(chunk_fn, jnp.arange(n_chunks))

    Dv = v.shape[-1]
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * q_chunk, H, Dv)
    return out[:, :Sq]


def _attend(q_i, k_i, v_i, mask, G):
    """q_i: [B, c, H, D]; k_i: [B, s, Kh, D]; v_i: [B, s, Kh, Dv]; mask: [c, s]."""
    B, c, H, D = q_i.shape
    Kh = k_i.shape[2]
    Dv = v_i.shape[-1]
    qg = q_i.reshape(B, c, Kh, G, D)
    scores = jnp.einsum("bckgd,bskd->bkgcs", qg.astype(jnp.float32),
                        k_i.astype(jnp.float32))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskv->bckgv", probs, v_i.astype(jnp.float32))
    return out.reshape(B, c, H, Dv).astype(q_i.dtype)


# ---------------------------------------------------------------------------
# Decode attention against a flattened cache
# ---------------------------------------------------------------------------
def lengths_vector(cache_len, B):
    """Normalize a scalar-or-[B] cache length to a [B] int32 vector."""
    v = jnp.asarray(cache_len, jnp.int32)
    return jnp.broadcast_to(v, (B,)) if v.ndim == 0 else v


def decode_attention(q, k_cache, v_cache, cache_len, *, n_kv_heads: int,
                     ring: bool = False, window: int = 0):
    """q: [B, 1, H, D]; caches: [B, S_cache, Kh*D] (keys stored post-RoPE).

    ``cache_len`` may be a scalar or a per-request [B] vector (the engine
    batches heterogeneous contexts).  ``ring``: sliding-window ring buffer —
    every slot written so far is valid.
    """
    B, _, H, D = q.shape
    S = k_cache.shape[1]
    Kh = n_kv_heads
    G = H // Kh
    k = k_cache.reshape(B, S, Kh, D)
    v = v_cache.reshape(B, S, Kh, D)
    scale = 1.0 / math.sqrt(D)
    qg = (q[:, 0] * scale).reshape(B, Kh, G, D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    n_valid = jnp.minimum(lengths_vector(cache_len, B) + 1, S)
    valid = jnp.arange(S)[None, None, None, :] < n_valid[:, None, None, None]
    if window and not ring:
        # full-length cache with a sliding window: only the last `window`
        # positions are visible (ring caches restrict physically instead)
        lo = (n_valid - window)[:, None, None, None]
        valid &= jnp.arange(S)[None, None, None, :] >= lo
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, H * D).astype(q.dtype)


def cache_write(cache, new, index):
    """Write new [B, T, kv_dim] at position ``index`` (scalar or [B])."""
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), idx, axis=1)
    # per-request positions: masked one-token write (T must be 1)
    B, S = cache.shape[:2]
    mask = (jnp.arange(S)[None, :] == idx[:, None])[..., None]
    return jnp.where(mask, new.astype(cache.dtype), cache)


def ring_write(cache, new, index):
    """Ring-buffer write of a single token at slot index % S."""
    S = cache.shape[1]
    return cache_write(cache, new, jnp.asarray(index, jnp.int32) % S)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def _ffn_spec(h):
    return ("dp",) + (None,) * (h.ndim - 2) + ("model",)


def gated_mlp(p, x, act: str):
    a = act_fn(act)
    h = a(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, *_ffn_spec(h))
    return h @ p["w_down"]


def plain_mlp(p, x, act: str):
    a = act_fn(act)
    h = a(x @ p["w_up"])
    h = constrain(h, *_ffn_spec(h))
    return h @ p["w_down"]


def mlp(p, x, act: str):
    if "w_gate" in p:
        return gated_mlp(p, x, act)
    return plain_mlp(p, x, act)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    if len(shape) == 3:  # [experts, in, out]
        fan_in = shape[1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
