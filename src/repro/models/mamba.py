"""Mamba-1 (falcon-mamba) and Mamba-2 (zamba2) blocks.

Full-sequence forward uses ``lax.scan`` over time (prefill / training) and a
single-step state update for decode.  ``d_inner`` shards over the "model"
mesh axis — the recurrence is elementwise in ``d_inner`` so the scan is
tensor-parallel with zero per-step communication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.sharding import constrain


# ---------------------------------------------------------------------------
# causal depthwise conv (kernel K, unrolled shifts — K is 4)
# ---------------------------------------------------------------------------
def causal_conv(x, w, b, prefix=None, n_valid=None):
    """x: [B, S, C]; w: [K, C]; prefix: [B, K-1, C] carried state or None.

    ``n_valid``: optional [B] count of *valid* leading positions when the
    batch carries right-padded variable-length chunks — the carried prefix
    is then taken at each request's own boundary (the last K-1 real tokens)
    instead of the padded tail.  Valid outputs only read backwards, so they
    are unaffected by the padding.
    """
    K = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, j:j + S] * w[j] for j in range(K))
    y = y + b
    if K > 1:
        if n_valid is not None:
            # xp index n_valid[b] .. n_valid[b]+K-2 = real positions
            # n_valid-K+1 .. n_valid-1 (prefix rows fill in when short)
            idx = n_valid[:, None] + jnp.arange(K - 1)[None, :]
            new_prefix = jnp.take_along_axis(xp, idx[..., None], axis=1)
        else:
            new_prefix = xp[:, -(K - 1):]
    else:
        new_prefix = prefix
    return jax.nn.silu(y), new_prefix


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------
def init_mamba1(key, cfg, dtype):
    d, di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.conv_kernel
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.zeros((d,), jnp.float32),
        "in_proj": layers.dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": layers.dense_init(ks[1], (K, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.dense_init(ks[2], (di, R + 2 * N), dtype),
        "dt_proj": layers.dense_init(ks[3], (R, di), dtype),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),  # softplus ~= 0.018
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], (di, d), dtype),
    }


def _ssm1_step(h, inputs, A):
    """h: [B, di, N]; dt/x: [B, di]; Bt/Ct: [B, N]."""
    dt, x, Bt, Ct = inputs
    dA = jnp.exp(dt[..., None] * A)                       # [B, di, N]
    dBx = (dt * x)[..., None] * Bt[:, None, :]            # [B, di, N]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, Ct)
    return h, y


def mamba1_seq(p, x, cfg, state=None, conv_prefix=None, mask=None):
    """Full-sequence Mamba-1.  x: [B, S, d] -> (y, (state, conv_prefix)).

    ``mask``: optional [B, S] bool marking valid positions of right-padded
    variable-length chunks.  Padded positions freeze the recurrence
    (dt -> 0: dA = 1, dBx = 0) and the conv prefix is carried from each
    request's own boundary, so the returned state matches running the
    unpadded sequence; padded outputs are garbage the caller discards.
    """
    B, S, d = x.shape
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    n_valid = None if mask is None else jnp.sum(mask, axis=1).astype(jnp.int32)
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "dp", None, "model")
    xc, conv_prefix = causal_conv(xin, p["conv_w"], p["conv_b"], conv_prefix,
                                  n_valid)

    proj = xc @ p["x_proj"]                                # [B, S, R+2N]
    dt_raw, Bt, Ct = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] +
                         p["dt_bias"].astype(dt_raw.dtype))  # [B, S, di]
    if mask is not None:
        dt = dt * mask[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"])                               # [di, N]

    if state is None:
        state = jnp.zeros((B, di, N), jnp.float32)
    seq = (jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
           jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
           jnp.moveaxis(Bt.astype(jnp.float32), 1, 0),
           jnp.moveaxis(Ct.astype(jnp.float32), 1, 0))
    state, ys = jax.lax.scan(lambda h, s: _ssm1_step(h, s, A), state, seq)
    y = jnp.moveaxis(ys, 0, 1)                             # [B, S, di]
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, "dp", None, "model")
    return y @ p["out_proj"], (state, conv_prefix)


def mamba1_decode(p, x, cfg, state, conv_prefix):
    """One token.  x: [B, 1, d]."""
    y, (state, conv_prefix) = mamba1_seq(p, x, cfg, state, conv_prefix)
    return y, (state, conv_prefix)


def mamba1_cache_shape(cfg, batch):
    return {
        "state": (batch, cfg.d_inner, cfg.ssm_state),
        "conv": (batch, cfg.conv_kernel - 1, cfg.d_inner),
    }


# ---------------------------------------------------------------------------
# Mamba-2 (SSD with scalar A per head)
# ---------------------------------------------------------------------------
def init_mamba2(key, cfg, dtype):
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.conv_kernel
    H2 = di // cfg.mamba2_head_dim
    ks = jax.random.split(key, 6)
    conv_dim = di + 2 * N
    return {
        "norm": jnp.zeros((d,), jnp.float32),
        "in_proj": layers.dense_init(ks[0], (d, 2 * di), dtype),
        "bc_proj": layers.dense_init(ks[1], (d, 2 * N), dtype),
        "dtp": layers.dense_init(ks[2], (d, H2), dtype),
        "conv_w": layers.dense_init(ks[3], (K, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias2": jnp.full((H2,), -4.0, jnp.float32),
        "A_log2": jnp.zeros((H2,), jnp.float32),
        "D2": jnp.ones((H2,), jnp.float32),
        "ssm_norm": jnp.zeros((di,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], (di, d), dtype),
    }


def _ssm2_step(h, inputs, A):
    """h: [B, H, hd, N]; x: [B, H, hd]; Bt/Ct: [B, N]; dt: [B, H]."""
    dt, x, Bt, Ct = inputs
    dA = jnp.exp(dt * A)                                   # [B, H]
    h = dA[..., None, None] * h + (dt[..., None] * x)[..., None] * Bt[:, None, None, :]
    y = jnp.einsum("bhdn,bn->bhd", h, Ct)
    return h, y


def mamba2_seq(p, x, cfg, state=None, conv_prefix=None, mask=None):
    """``mask``: see :func:`mamba1_seq` — freezes the recurrence at padded
    positions of right-padded variable-length chunks."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    hd = cfg.mamba2_head_dim
    H2 = di // hd
    n_valid = None if mask is None else jnp.sum(mask, axis=1).astype(jnp.int32)
    xz = x @ p["in_proj"]
    z, xin = jnp.split(xz, 2, axis=-1)
    bc = x @ p["bc_proj"]
    dt = jax.nn.softplus(x @ p["dtp"] + p["dt_bias2"].astype(x.dtype))  # [B,S,H2]
    if mask is not None:
        dt = dt * mask[..., None].astype(dt.dtype)

    xbc = jnp.concatenate([xin, bc], axis=-1)
    xbc = constrain(xbc, "dp", None, None)
    xbc, conv_prefix = causal_conv(xbc, p["conv_w"], p["conv_b"], conv_prefix,
                                   n_valid)
    xc, Bt, Ct = jnp.split(xbc, [di, di + N], axis=-1)
    xh = xc.reshape(B, S, H2, hd)

    A = -jnp.exp(p["A_log2"])                              # [H2]
    if state is None:
        state = jnp.zeros((B, H2, hd, N), jnp.float32)
    seq = (jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
           jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
           jnp.moveaxis(Bt.astype(jnp.float32), 1, 0),
           jnp.moveaxis(Ct.astype(jnp.float32), 1, 0))
    state, ys = jax.lax.scan(lambda h, s: _ssm2_step(h, s, A), state, seq)
    y = jnp.moveaxis(ys, 0, 1)                             # [B, S, H2, hd]
    y = y + p["D2"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rmsnorm(y.astype(x.dtype), p["ssm_norm"], cfg.norm_eps)
    y = constrain(y, "dp", None, "model")
    return y @ p["out_proj"], (state, conv_prefix)


def mamba2_decode(p, x, cfg, state, conv_prefix):
    return mamba2_seq(p, x, cfg, state, conv_prefix)


def mamba2_cache_shape(cfg, batch):
    hd = cfg.mamba2_head_dim
    H2 = cfg.d_inner // hd
    return {
        "state": (batch, H2, hd, cfg.ssm_state),
        "conv": (batch, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.ssm_state),
    }
