"""Multi-head Latent Attention (DeepSeek-V2).

Prefill/train run the uncompressed path (expand kv_b, standard MHA);
decode runs the *absorbed* path against the compressed cache — the cache
stores only the kv_lora latent + the shared RoPE key, so the per-token
cache is ``kv_lora_rank + qk_rope_head_dim`` wide (576 for DeepSeek-V2)
instead of ``2 * H * head_dim`` (32768).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.sharding import constrain


def init_mla(key, cfg, dtype):
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    p = {
        "kv_a": layers.dense_init(ks[0], (d, cfg.kv_lora_rank + rope_d), dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), jnp.float32),
        "kv_b": layers.dense_init(ks[1], (cfg.kv_lora_rank, H * (nope + vd)), dtype),
        "wo": layers.dense_init(ks[2], (H * vd, d), dtype),
    }
    if cfg.q_lora_rank:
        p["q_a"] = layers.dense_init(ks[3], (d, cfg.q_lora_rank), dtype)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), jnp.float32)
        p["q_b"] = layers.dense_init(ks[4], (cfg.q_lora_rank, H * (nope + rope_d)), dtype)
    else:
        p["q_b"] = layers.dense_init(ks[4], (d, H * (nope + rope_d)), dtype)
    return p


def _queries(p, x, cfg, positions):
    B = x.shape[0]
    S = x.shape[1]
    H, nope, rope_d = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "q_a" in p:
        qh = layers.rmsnorm(x @ p["q_a"], p["q_norm"], cfg.norm_eps) @ p["q_b"]
    else:
        qh = x @ p["q_b"]
    qh = qh.reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = jnp.split(qh, [nope], axis=-1)
    q_rope = layers.rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(p, x, cfg, positions):
    """Returns (ckv [B,S,kv_lora] post-norm, k_rope [B,S,rope_d] post-rope)."""
    ckv_full = x @ p["kv_a"]
    ckv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    ckv = layers.rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = layers.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope


def mla_full(p, x, cfg, positions):
    """Uncompressed MHA path for train/prefill.  Returns (out, (ckv, k_rope))."""
    B, S, _ = x.shape
    H, nope, rope_d, vd = (cfg.num_heads, cfg.qk_nope_head_dim,
                           cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_nope, q_rope = _queries(p, x, cfg, positions)
    ckv, k_rope = _latent_kv(p, x, cfg, positions)
    kv = (ckv @ p["kv_b"]).reshape(B, S, H, nope + vd)
    k_nope, v = jnp.split(kv, [nope], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))],
        axis=-1)
    o = layers.blockwise_attention(q, k, v, causal=True)
    o = o.reshape(B, S, H * vd)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], (ckv, k_rope)


def mla_decode(p, x, cfg, ckv_cache, krope_cache, cache_len):
    """Absorbed decode.  x: [B, 1, d]; caches: [B, S, kv_lora], [B, S, rope_d].

    Returns (out [B,1,d], new ckv token, new k_rope token).
    """
    B = x.shape[0]
    H, nope, rope_d, vd = (cfg.num_heads, cfg.qk_nope_head_dim,
                           cfg.qk_rope_head_dim, cfg.v_head_dim)
    R = cfg.kv_lora_rank
    pos = layers.lengths_vector(cache_len, B)[:, None]
    q_nope, q_rope = _queries(p, x, cfg, pos)               # [B,1,H,*]
    ckv_new, krope_new = _latent_kv(p, x, cfg, pos)          # [B,1,R], [B,1,rope_d]
    ckv_cache = layers.cache_write(ckv_cache, ckv_new, cache_len)
    krope_cache = layers.cache_write(krope_cache, krope_new, cache_len)

    kv_b = p["kv_b"].reshape(R, H, nope + vd)
    w_uk = kv_b[..., :nope]                                  # [R, H, nope]
    w_uv = kv_b[..., nope:]                                  # [R, H, vd]

    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # [B,H,R]
    scale = 1.0 / math.sqrt(nope + rope_d)
    scores = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache.astype(jnp.float32)) +
              jnp.einsum("bhe,bse->bhs", q_rope[:, 0].astype(jnp.float32),
                         krope_cache.astype(jnp.float32))) * scale
    S = ckv_cache.shape[1]
    n_valid = layers.lengths_vector(cache_len, B) + 1
    valid = jnp.arange(S)[None, None, :] < n_valid[:, None, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", ctx_lat, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * vd).astype(x.dtype)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], ckv_cache, krope_cache


def mla_decode_paged(p, x, cfg, data, layer, tables, slots, lens, *,
                     interpret: bool = True, use_kernel: bool = True):
    """Absorbed MLA decode over the device-resident paged latent cache.

    The compressed cache makes absorbed MLA *exactly* MQA with one shared
    KV head: the key of token s is its stored row ``[ckv_s, krope_s]`` and
    ``probs @ ckv == ctx_lat``, so the generic paged-attention kernel serves
    MLA with ``k_pages == v_pages`` and the latent context read off the
    first ``kv_lora_rank`` output features.

    x: [B, 1, d]; data: [1, L_mla, num_blocks, bs, R+rope_d];
    tables: [B, P]; slots: [B]; lens: [B] tokens already cached.
    Returns (out [B, 1, d], updated data).
    """
    from repro.kernels.cache_write.ops import paged_token_write
    from repro.kernels.paged_attention.ops import paged_attention

    B = x.shape[0]
    H, nope, rope_d, vd = (cfg.num_heads, cfg.qk_nope_head_dim,
                           cfg.qk_rope_head_dim, cfg.v_head_dim)
    R = cfg.kv_lora_rank
    pos = layers.lengths_vector(lens, B)[:, None]
    q_nope, q_rope = _queries(p, x, cfg, pos)                # [B,1,H,*]
    ckv_new, krope_new = _latent_kv(p, x, cfg, pos)          # [B,1,R]/[B,1,rope]
    rows = jnp.concatenate([ckv_new[:, 0], krope_new[:, 0]], -1)[None]
    data = paged_token_write(data, layer, rows.astype(data.dtype), slots,
                             interpret=interpret, use_kernel=use_kernel)
    NB, bs = data.shape[2], data.shape[3]
    pages = data[0, layer].reshape(NB, bs, 1, R + rope_d)

    kv_b = p["kv_b"].reshape(R, H, nope + vd)
    w_uk, w_uv = kv_b[..., :nope], kv_b[..., nope:]
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # [B,H,R]
    q_cat = jnp.concatenate([q_lat, q_rope[:, 0].astype(jnp.float32)], -1)
    # the kernel scales by 1/sqrt(R+rope_d); MLA wants 1/sqrt(nope+rope_d)
    q_cat = q_cat * (math.sqrt(R + rope_d) / math.sqrt(nope + rope_d))
    ctx = paged_attention(q_cat.astype(pages.dtype), pages, pages, tables,
                          lens + 1, interpret=interpret, use_kernel=use_kernel)
    ctx_lat = ctx[..., :R].astype(jnp.float32)               # [B,H,R]
    o = jnp.einsum("bhr,rhv->bhv", ctx_lat, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * vd).astype(x.dtype)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], data


def mla_chunk_paged(p, x, cfg, data, layer, tables, slots, ctx_lens, *,
                    interpret: bool = True, use_kernel: bool = True):
    """Chunked-prefill MLA over the device-resident paged latent cache.

    The absorbed factorization (see :func:`mla_decode_paged`) is exact, so
    prefill can use it too: write the chunk's ``[ckv, k_rope]`` rows into
    the pages with one fused launch, then run the *chunked* paged-attention
    kernel as 1-head MQA — query c at position ``ctx_lens[b] + c`` sees the
    prior context plus the chunk prefix through the chunk-causal mask, and
    the latent context is the first ``kv_lora_rank`` output features.

    x: [B, C, d]; data: [1, L_mla, num_blocks, bs, R+rope_d];
    tables: [B, P]; slots: [B, C] (padded positions point at scratch);
    ctx_lens: [B] tokens cached before the chunk.
    Returns (out [B, C, d], updated data).
    """
    from repro.kernels.cache_write.ops import paged_chunk_write
    from repro.kernels.paged_attention.ops import paged_prefill_attention

    B, C, _ = x.shape
    H, nope, rope_d, vd = (cfg.num_heads, cfg.qk_nope_head_dim,
                           cfg.qk_rope_head_dim, cfg.v_head_dim)
    R = cfg.kv_lora_rank
    pos = ctx_lens[:, None] + jnp.arange(C)                  # [B, C]
    q_nope, q_rope = _queries(p, x, cfg, pos)                # [B, C, H, *]
    ckv_new, krope_new = _latent_kv(p, x, cfg, pos)          # [B,C,R]/[B,C,rope]
    rows = jnp.concatenate([ckv_new, krope_new], -1)[None]   # [1, B, C, R+rope]
    data = paged_chunk_write(data, layer, rows.astype(data.dtype), slots,
                             interpret=interpret, use_kernel=use_kernel)
    NB, bs = data.shape[2], data.shape[3]
    pages = data[0, layer].reshape(NB, bs, 1, R + rope_d)

    kv_b = p["kv_b"].reshape(R, H, nope + vd)
    w_uk, w_uv = kv_b[..., :nope], kv_b[..., nope:]
    q_lat = jnp.einsum("bchn,rhn->bchr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # [B,C,H,R]
    q_cat = jnp.concatenate([q_lat, q_rope.astype(jnp.float32)], -1)
    # the kernel scales by 1/sqrt(R+rope_d); MLA wants 1/sqrt(nope+rope_d)
    q_cat = q_cat * (math.sqrt(R + rope_d) / math.sqrt(nope + rope_d))
    ctx = paged_prefill_attention(q_cat.astype(pages.dtype), pages, pages,
                                  tables, ctx_lens, interpret=interpret,
                                  use_kernel=use_kernel)
    ctx_lat = ctx[..., :R].astype(jnp.float32)               # [B,C,H,R]
    o = jnp.einsum("bchr,rhv->bchv", ctx_lat, w_uv.astype(jnp.float32))
    o = o.reshape(B, C, H * vd).astype(x.dtype)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], data


def mla_chunk(p, x, cfg, ckv_prior, krope_prior, offset):
    """Chunked-prefill MLA: extend a compressed-cache prefix by a chunk.

    x: [B, C, d]; priors: [B, P, kv_lora] / [B, P, rope_d].  Uses the
    uncompressed path over concat(prefix, chunk) keys.
    """
    B, C, _ = x.shape
    H, nope, rope_d, vd = (cfg.num_heads, cfg.qk_nope_head_dim,
                           cfg.qk_rope_head_dim, cfg.v_head_dim)
    positions = offset + jnp.arange(C)
    q_nope, q_rope = _queries(p, x, cfg, positions)
    ckv_new, krope_new = _latent_kv(p, x, cfg, positions)
    ckv_all = jnp.concatenate([ckv_prior.astype(ckv_new.dtype), ckv_new], axis=1)
    krope_all = jnp.concatenate([krope_prior.astype(krope_new.dtype),
                                 krope_new], axis=1)
    S = ckv_all.shape[1]
    kv = (ckv_all @ p["kv_b"]).reshape(B, S, H, nope + vd)
    k_nope, v = jnp.split(kv, [nope], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_all[:, :, None, :], (B, S, H, rope_d))],
        axis=-1)
    P_len = ckv_prior.shape[1]
    o = layers.blockwise_attention(q, k, v, causal=True, kv_offset=P_len)
    o = o.reshape(B, C, H * vd)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], (ckv_new, krope_new)
