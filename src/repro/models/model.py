"""Unified multi-architecture decoder.

One generic stack with per-layer *kind* dispatch covers all ten assigned
architectures plus the paper's own LLaVA-1.5 model:

  - ATTN_MLP / ATTN_MOE : dense GQA attention (+ optional sliding window,
    optional whisper cross-attention) + gated/plain MLP or MoE FFN
  - MLA_MLP / MLA_MOE   : DeepSeek-V2 multi-head latent attention
  - MAMBA1 / MAMBA2     : selective-scan SSM blocks
  - SHARED_ATTN         : Zamba-style shared attention+MLP block

Public API (all pure functions of (cfg, params, ...)):
  init_params / param_specs
  forward        - full-sequence logits (train / eval)
  prefill        - full-sequence + per-layer caches (serving prefill)
  init_cache / cache_specs / cache_pspecs
  decode_step    - one token against the cache
  encode_media   - the encode-stage computation (projector / audio encoder)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_MLP, ATTN_MOE, MLA_MLP, MLA_MOE, MAMBA1,
                                MAMBA2, SHARED_ATTN, ModelConfig)
from repro.models import layers, mamba, mla, moe
from repro.models.layers import rmsnorm
from repro.models.sharding import constrain

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_attn(key, cfg, dtype, cross: bool):
    d, H, Kh, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 9)
    p = {
        "norm1": jnp.zeros((d,), jnp.float32),
        "wq": layers.dense_init(ks[0], (d, H * Dh), dtype),
        "wk": layers.dense_init(ks[1], (d, Kh * Dh), dtype),
        "wv": layers.dense_init(ks[2], (d, Kh * Dh), dtype),
        "wo": layers.dense_init(ks[3], (H * Dh, d), dtype),
        "norm2": jnp.zeros((d,), jnp.float32),
    }
    if cross:
        p.update({
            "xnorm": jnp.zeros((d,), jnp.float32),
            "xq": layers.dense_init(ks[4], (d, H * Dh), dtype),
            "xk": layers.dense_init(ks[5], (d, Kh * Dh), dtype),
            "xv": layers.dense_init(ks[6], (d, Kh * Dh), dtype),
            "xo": layers.dense_init(ks[7], (H * Dh, d), dtype),
        })
    return p


def _init_mlp(key, cfg, dtype, d_ff: int):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu_mlp":  # plain (whisper)
        return {"w_up": layers.dense_init(ks[0], (d, d_ff), dtype),
                "w_down": layers.dense_init(ks[1], (d_ff, d), dtype)}
    return {"w_gate": layers.dense_init(ks[0], (d, d_ff), dtype),
            "w_up": layers.dense_init(ks[1], (d, d_ff), dtype),
            "w_down": layers.dense_init(ks[2], (d_ff, d), dtype)}


def _init_layer(key, cfg, kind, dtype):
    k1, k2 = jax.random.split(key)
    if kind == MAMBA1:
        return mamba.init_mamba1(k1, cfg, dtype)
    if kind == MAMBA2:
        return mamba.init_mamba2(k1, cfg, dtype)
    if kind == SHARED_ATTN:
        return {"norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind in (MLA_MLP, MLA_MOE):
        p = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32),
             "norm2": jnp.zeros((cfg.d_model,), jnp.float32)}
        p.update(mla.init_mla(k1, cfg, dtype))
    else:
        p = _init_attn(k1, cfg, dtype, cross=cfg.cross_attention)
    if kind in (ATTN_MOE, MLA_MOE):
        p.update(moe.init_moe(k2, cfg, dtype))
    else:
        p.update(_init_mlp(k2, cfg, dtype, cfg.d_ff))
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    kinds = cfg.layer_kinds()
    n_keys = cfg.num_layers + 8 + cfg.encoder_layers
    ks = list(jax.random.split(key, n_keys))
    params = {
        "embed": layers.dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                                   scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": [_init_layer(ks[2 + i], cfg, kind, dtype)
                   for i, kind in enumerate(kinds)],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                              dtype, scale=0.02)
    if any(k == SHARED_ATTN for k in kinds):
        sp = _init_attn(ks[-1], cfg, dtype, cross=False)
        sp.update(_init_mlp(ks[-2], cfg, dtype, cfg.d_ff))
        params["shared"] = sp
    if cfg.frontend == "vision":
        d = cfg.d_model
        params["media_proj_w1"] = layers.dense_init(ks[-3], (d, 2 * d), dtype)
        params["media_proj_w2"] = layers.dense_init(ks[-4], (2 * d, d), dtype)
    if cfg.encoder_layers:
        off = 8 + cfg.num_layers
        params["encoder"] = {
            "layers": [_init_attn(ks[off + i], cfg, dtype, cross=False)
                       | _init_mlp(jax.random.fold_in(ks[off + i], 1), cfg,
                                   dtype, cfg.d_ff)
                       for i in range(cfg.encoder_layers)],
            "norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return params


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype), key)


# ---------------------------------------------------------------------------
# sub-layers (full sequence)
# ---------------------------------------------------------------------------
def _attn_full(p, x, cfg, positions, window, causal=True):
    B, S, _ = x.shape
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    k = (x @ p["wk"]).reshape(B, S, Kh, Dh)
    v = (x @ p["wv"]).reshape(B, S, Kh, Dh)
    if cfg.rope_theta:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    o = layers.blockwise_attention(q, k, v, causal=causal, window=window)
    o = o.reshape(B, S, H * Dh)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], (k.reshape(B, S, Kh * Dh), v.reshape(B, S, Kh * Dh))


def _cross_full(p, x, enc_out, cfg):
    B, S, _ = x.shape
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    T = enc_out.shape[1]
    q = (x @ p["xq"]).reshape(B, S, H, Dh)
    k = (enc_out @ p["xk"]).reshape(B, T, Kh, Dh)
    v = (enc_out @ p["xv"]).reshape(B, T, Kh, Dh)
    o = layers.blockwise_attention(q, k, v, causal=False)
    o = o.reshape(B, S, H * Dh)
    o = constrain(o, "dp", None, "model")
    return o @ p["xo"], (k.reshape(B, T, Kh * Dh), v.reshape(B, T, Kh * Dh))


def _ffn(p, x, cfg, kind, lossless_moe=False):
    if kind in (ATTN_MOE, MLA_MOE):
        from repro.models.sharding import current_mesh
        mesh = current_mesh()
        if moe.MOE_SHARDMAP and mesh is not None and not lossless_moe \
                and cfg.num_experts % mesh.shape["model"] == 0:
            return moe.moe_ffn_shardmap(p, x, cfg, mesh)
        return moe.moe_ffn(p, x, cfg, lossless=lossless_moe)
    return layers.mlp(p, x, cfg.act), 0.0


def _block_full(cfg, kind, p, shared, h, positions, enc_out, window,
                collect_cache):
    """Apply one block.  Returns (h, cache_entry, aux_loss)."""
    cache = {}
    aux = 0.0
    if kind in (MAMBA1, MAMBA2):
        fn = mamba.mamba1_seq if kind == MAMBA1 else mamba.mamba2_seq
        y, (state, conv) = fn(p, rmsnorm(h, p["norm"], cfg.norm_eps), cfg)
        h = h + y
        if collect_cache:
            cache = {"state": state, "conv": conv}
    elif kind == SHARED_ATTN:
        x_in = rmsnorm(h, p["norm"], cfg.norm_eps)
        a, (k, v) = _attn_full(shared, x_in, cfg, positions, window=0)
        h = h + a
        f = layers.mlp(shared, rmsnorm(h, shared["norm2"], cfg.norm_eps), cfg.act)
        h = h + f
        if collect_cache:
            cache = {"k": k, "v": v}
    elif kind in (MLA_MLP, MLA_MOE):
        a, (ckv, krope) = mla.mla_full(p, rmsnorm(h, p["norm1"], cfg.norm_eps),
                                       cfg, positions)
        h = h + a
        f, aux = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind)
        h = h + f
        if collect_cache:
            cache = {"ckv": ckv, "krope": krope}
    else:  # ATTN_MLP / ATTN_MOE
        a, (k, v) = _attn_full(p, rmsnorm(h, p["norm1"], cfg.norm_eps), cfg,
                               positions, window)
        h = h + a
        if collect_cache:
            cache = {"k": k, "v": v}
        if cfg.cross_attention:
            c, (xk, xv) = _cross_full(p, rmsnorm(h, p["xnorm"], cfg.norm_eps),
                                      enc_out, cfg)
            h = h + c
            if collect_cache:
                cache.update({"xk": xk, "xv": xv})
        f, aux = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind)
        h = h + f
    return h, cache, aux


# ---------------------------------------------------------------------------
# encode stage / embedding
# ---------------------------------------------------------------------------
def encode_media(cfg, params, media):
    """The encode-stage computation: vision projector or audio encoder."""
    if cfg.frontend == "vision":
        h = jax.nn.gelu((media @ params["media_proj_w1"]), approximate=True)
        return h @ params["media_proj_w2"]
    if cfg.frontend == "audio":
        enc = params["encoder"]
        T = media.shape[1]
        h = media + layers.sinusoidal_positions(
            jnp.arange(T), cfg.d_model, media.dtype)
        pos = jnp.arange(T)
        for lp in enc["layers"]:
            a, _ = _attn_full(lp, rmsnorm(h, lp["norm1"], cfg.norm_eps), cfg,
                              pos, window=0, causal=False)
            h = h + a
            h = h + layers.mlp(lp, rmsnorm(h, lp["norm2"], cfg.norm_eps), cfg.act)
        return rmsnorm(h, enc["norm"], cfg.norm_eps)
    return media


def _embed(cfg, params, tokens, media_emb, positions):
    h = params["embed"][tokens]
    if media_emb is not None:
        h = jnp.concatenate([media_emb.astype(h.dtype), h], axis=1)
    if not cfg.rope_theta:  # absolute sinusoidal positions (whisper)
        h = h + layers.sinusoidal_positions(positions, cfg.d_model, h.dtype)
    return constrain(h, "dp", None, None)


def _logits(cfg, params, h):
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w
    return constrain(logits, "dp", None, "model") if logits.ndim == 3 \
        else constrain(logits, "dp", "model")


# ---------------------------------------------------------------------------
# full-sequence forward / prefill
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, tokens, media=None, frames=None, *,
            remat: bool = False, collect_cache: bool = False):
    """Returns (logits [B, S_total, V], caches | None, aux_loss)."""
    enc_out = None
    media_emb = None
    if frames is not None:
        enc_out = encode_media(cfg, params, frames)
    if media is not None:
        media_emb = encode_media(cfg, params, media)
    S_total = tokens.shape[1] + (media_emb.shape[1] if media_emb is not None else 0)
    positions = jnp.arange(S_total)
    h = _embed(cfg, params, tokens, media_emb, positions)

    caches = []
    aux_total = 0.0
    seq_shard = cfg.family not in ("ssm", "hybrid")
    for i, kind in enumerate(cfg.layer_kinds()):
        window = cfg.sliding_window if cfg.is_local_layer(i) else 0
        p = params["layers"][i]
        shared = params.get("shared")

        def body(h, p, shared):
            return _block_full(cfg, kind, p, shared, h, positions, enc_out,
                               window, collect_cache)

        if remat:
            body = jax.checkpoint(body)
        h, cache, aux = body(h, p, shared)
        if seq_shard:
            h = constrain(h, "dp", "model", None)
        aux_total = aux_total + aux
        caches.append(cache)
    logits = _logits(cfg, params, h)
    return logits, (caches if collect_cache else None), aux_total


def prefill(cfg: ModelConfig, params, tokens, media=None, frames=None):
    """Serving prefill: returns (last-token logits [B, V], cache dict)."""
    logits, caches, _ = forward(cfg, params, tokens, media=media, frames=frames,
                                collect_cache=True)
    cache = {"layers": caches}
    return logits[:, -1], cache


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------
def _layer_cache_shape(cfg, kind, i, batch, max_len):
    if kind == MAMBA1:
        return {k: s for k, s in mamba.mamba1_cache_shape(cfg, batch).items()}
    if kind == MAMBA2:
        return {k: s for k, s in mamba.mamba2_cache_shape(cfg, batch).items()}
    if kind in (MLA_MLP, MLA_MOE):
        return {"ckv": (batch, max_len, cfg.kv_lora_rank),
                "krope": (batch, max_len, cfg.qk_rope_head_dim)}
    S_c = max_len
    if cfg.is_local_layer(i) and cfg.sliding_window:
        S_c = min(max_len, cfg.sliding_window)
    ent = {"k": (batch, S_c, cfg.num_kv_heads * cfg.head_dim),
           "v": (batch, S_c, cfg.num_kv_heads * cfg.head_dim)}
    if cfg.cross_attention and kind in (ATTN_MLP, ATTN_MOE):
        ent["xk"] = (batch, cfg.media_tokens, cfg.num_kv_heads * cfg.head_dim)
        ent["xv"] = (batch, cfg.media_tokens, cfg.num_kv_heads * cfg.head_dim)
    return ent


def _cache_tree(cfg, batch, max_len, leaf):
    out = []
    for i, kind in enumerate(cfg.layer_kinds()):
        shapes = _layer_cache_shape(cfg, kind, i, batch, max_len)
        ent = {}
        for name, shape in shapes.items():
            dtype = jnp.float32 if name == "state" else None
            ent[name] = leaf(shape, dtype)
        out.append(ent)
    return {"layers": out}


def init_cache(cfg, batch, max_len, dtype=jnp.float32):
    return _cache_tree(cfg, batch, max_len,
                       lambda s, dt: jnp.zeros(s, dt or dtype))


def cache_specs(cfg, batch, max_len, dtype=jnp.bfloat16):
    return _cache_tree(cfg, batch, max_len,
                       lambda s, dt: jax.ShapeDtypeStruct(s, dt or dtype))


def cache_pspecs(cfg, layout: str = "kvdim"):
    """PartitionSpecs matching the cache tree (for in_shardings).

    layout="kvdim" (paper-faithful baseline): shard the flattened
    kv_heads*head_dim feature dim over "model" — matches the weight layout
    but forces GSPMD to all-gather the cache for per-head attention when
    kv_heads doesn't divide the model axis.

    layout="seq" (beyond-paper): shard the cache SEQUENCE dim over "model"
    (ring-attention-style decode) — each device scores its context slice,
    softmax combines with tiny [B,H] collectives, and the new token's write
    lands on one shard.
    """
    from jax.sharding import PartitionSpec as P

    def leaf_spec(name, ndim):
        if name in ("k", "v", "xk", "xv", "conv"):
            if layout == "seq" and name in ("k", "v"):
                return ("dp", "model", None)
            return ("dp", None, "model")
        if name in ("ckv", "krope") and layout == "seq":
            return ("dp", "model", None)
        if name == "state":
            return ("dp", "model") + (None,) * (ndim - 2)
        return ("dp",) + (None,) * (ndim - 1)  # ckv / krope replicated on model

    out = []
    for i, kind in enumerate(cfg.layer_kinds()):
        shapes = _layer_cache_shape(cfg, kind, i, batch=1, max_len=2)
        out.append({name: leaf_spec(name, len(s)) for name, s in shapes.items()})
    return {"layers": out}


def build_cache_from_prefill(cfg, prefill_cache, max_len):
    """Pad/arrange prefill per-layer entries into fixed-size decode caches."""
    out = []
    for i, (kind, ent) in enumerate(zip(cfg.layer_kinds(), prefill_cache["layers"])):
        if kind in (MAMBA1, MAMBA2):
            out.append(ent)
            continue
        new = {}
        for name, arr in ent.items():
            if name in ("xk", "xv"):
                new[name] = arr
                continue
            S = arr.shape[1]
            S_c = max_len
            if name in ("k", "v") and cfg.is_local_layer(i) and cfg.sliding_window:
                S_c = min(max_len, cfg.sliding_window)
            if S >= S_c:  # ring: keep the last S_c entries at slot = pos % S_c
                tail = arr[:, S - S_c:]
                new[name] = jnp.roll(tail, S % S_c, axis=1)
            else:
                pad = jnp.zeros((arr.shape[0], S_c - S) + arr.shape[2:], arr.dtype)
                new[name] = jnp.concatenate([arr, pad], axis=1)
        out.append(new)
    return {"layers": out}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def _attn_decode(p, x, cfg, ent, cache_len, window):
    B = x.shape[0]
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = layers.lengths_vector(cache_len, B)[:, None]
    q = (x @ p["wq"]).reshape(B, 1, H, Dh)
    k = (x @ p["wk"]).reshape(B, 1, Kh, Dh)
    v = (x @ p["wv"]).reshape(B, 1, Kh, Dh)
    if cfg.rope_theta:
        q = layers.rope(q, pos, cfg.rope_theta)
        k = layers.rope(k, pos, cfg.rope_theta)
    k_flat = k.reshape(B, 1, Kh * Dh)
    v_flat = v.reshape(B, 1, Kh * Dh)
    S_c = ent["k"].shape[1]
    ring = bool(window) and S_c <= window
    write = layers.ring_write if ring else layers.cache_write
    k_cache = write(ent["k"], k_flat, cache_len)
    v_cache = write(ent["v"], v_flat, cache_len)
    o = layers.decode_attention(q, k_cache, v_cache, cache_len,
                                n_kv_heads=Kh, ring=ring, window=window)
    o = constrain(o, "dp", None, "model")
    out = o @ p["wo"]
    return out, {**ent, "k": k_cache, "v": v_cache}


def _cross_decode(p, x, cfg, ent):
    B = x.shape[0]
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["xq"]).reshape(B, 1, H, Dh)
    T = ent["xk"].shape[1]
    o = layers.decode_attention(q, ent["xk"], ent["xv"], jnp.int32(T - 1),
                                n_kv_heads=Kh)
    o = constrain(o, "dp", None, "model")
    return o @ p["xo"]


def decode_step(cfg: ModelConfig, params, cache, cache_len, token):
    """One decode step.  token: [B, 1] int32.  Returns (logits [B,V], cache)."""
    B = token.shape[0]
    h = params["embed"][token]
    if not cfg.rope_theta:
        pos_b = layers.lengths_vector(cache_len, B)
        h = h + layers.sinusoidal_positions(pos_b, cfg.d_model, h.dtype)[:, None]
    h = constrain(h, "dp", None, None)

    new_layers = []
    for i, kind in enumerate(cfg.layer_kinds()):
        p = params["layers"][i]
        ent = cache["layers"][i]
        window = cfg.sliding_window if cfg.is_local_layer(i) else 0
        if kind in (MAMBA1, MAMBA2):
            fn = mamba.mamba1_decode if kind == MAMBA1 else mamba.mamba2_decode
            y, (state, conv) = fn(p, rmsnorm(h, p["norm"], cfg.norm_eps), cfg,
                                  ent["state"], ent["conv"])
            h = h + y
            new_layers.append({"state": state, "conv": conv})
        elif kind == SHARED_ATTN:
            sp = params["shared"]
            x_in = rmsnorm(h, p["norm"], cfg.norm_eps)
            a, ent2 = _attn_decode(sp, x_in, cfg, ent, cache_len, window=0)
            h = h + a
            h = h + layers.mlp(sp, rmsnorm(h, sp["norm2"], cfg.norm_eps), cfg.act)
            new_layers.append(ent2)
        elif kind in (MLA_MLP, MLA_MOE):
            a, ckv, krope = mla.mla_decode(
                p, rmsnorm(h, p["norm1"], cfg.norm_eps), cfg,
                ent["ckv"], ent["krope"], cache_len)
            h = h + a
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind,
                         lossless_moe=True)
            h = h + f
            new_layers.append({"ckv": ckv, "krope": krope})
        else:
            a, ent2 = _attn_decode(p, rmsnorm(h, p["norm1"], cfg.norm_eps),
                                   cfg, ent, cache_len, window)
            h = h + a
            if cfg.cross_attention:
                h = h + _cross_decode(p, rmsnorm(h, p["xnorm"], cfg.norm_eps),
                                      cfg, ent)
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind,
                         lossless_moe=True)
            h = h + f
            new_layers.append(ent2)
    logits = _logits(cfg, params, h[:, 0])
    return logits, {"layers": new_layers}


# ---------------------------------------------------------------------------
# on-device sampling (DESIGN.md §13)
# ---------------------------------------------------------------------------
def sample_from_logits(logits, sample):
    """Batched categorical sampling with per-lane controls, fused into the
    jitted serving steps so only the sampled token ids [B] cross the host
    boundary (instead of [B, V] logits).

    ``sample``: {"temp": [B] f32, "top_k": [B] i32 (<=0 disables),
    "top_p": [B] f32, "seed": [B] u32, "step": [B] i32}.  Each lane draws
    its own PRNG key as ``fold_in(PRNGKey(seed), step)`` — a pure function
    of (request seed, token index), so sampling is deterministic no matter
    how requests are batched together.  Lanes with ``temp <= 0`` return the
    plain argmax, bit-exact with host-side greedy decoding.
    """
    temp = sample["temp"]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    V = logits.shape[-1]
    lg = logits / jnp.where(temp > 0, temp, 1.0)[:, None]
    # top-k: drop logits below each lane's k-th largest (k <= 0 disables)
    k = sample["top_k"]
    k_eff = jnp.clip(jnp.where(k > 0, k, V), 1, V)
    srt = jnp.flip(jnp.sort(lg, axis=-1), axis=-1)
    kth = jnp.take_along_axis(srt, (k_eff - 1)[:, None], axis=-1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)
    # top-p (nucleus): keep the smallest prefix of the descending
    # distribution whose mass reaches p; ties at the boundary stay in
    p = jnp.maximum(sample["top_p"], 1e-6)
    probs = jax.nn.softmax(lg, axis=-1)
    srt_p = jnp.flip(jnp.sort(probs, axis=-1), axis=-1)
    keep = (jnp.cumsum(srt_p, axis=-1) - srt_p) < p[:, None]
    pmin = jnp.min(jnp.where(keep, srt_p, jnp.inf), axis=-1)
    lg = jnp.where(probs >= pmin[:, None], lg, -jnp.inf)

    def gumbel(seed, step):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        return jax.random.gumbel(key, (V,), jnp.float32)

    noise = jax.vmap(gumbel)(sample["seed"], sample["step"])
    sampled = jnp.argmax(lg + noise, axis=-1).astype(jnp.int32)
    return jnp.where(temp <= 0, greedy, sampled)


# ---------------------------------------------------------------------------
# decode over device-resident paged caches (DESIGN.md §11)
# ---------------------------------------------------------------------------
def paged_impl_flags(attn_impl: str) -> dict:
    """Map an engine-level backend name onto the kernel ops' flag pair.

    kernel    : compiled Pallas kernels (TPU)
    interpret : Pallas kernels in interpret mode (CPU parity/testing)
    ref       : pure-jnp oracles (fast CPU path, same paged semantics)
    """
    if attn_impl == "kernel":
        return {"interpret": False, "use_kernel": True}
    if attn_impl == "interpret":
        return {"interpret": True, "use_kernel": True}
    if attn_impl == "ref":
        return {"interpret": True, "use_kernel": False}
    raise ValueError(f"unknown paged attention impl {attn_impl!r}")


def _attn_decode_paged(p, x, cfg, data, layer, tables, slots, lens, window,
                       flags):
    """Dense-attention decode step against the paged KV store: append the
    new token's K/V via the fused cache write, then attend through the
    paged-attention kernel over pages + block tables."""
    from repro.kernels.cache_write.ops import paged_token_write
    from repro.kernels.paged_attention.ops import paged_attention

    B = x.shape[0]
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = layers.lengths_vector(lens, B)[:, None]
    q = (x @ p["wq"]).reshape(B, 1, H, Dh)
    k = (x @ p["wk"]).reshape(B, 1, Kh, Dh)
    v = (x @ p["wv"]).reshape(B, 1, Kh, Dh)
    if cfg.rope_theta:
        q = layers.rope(q, pos, cfg.rope_theta)
        k = layers.rope(k, pos, cfg.rope_theta)
    rows = jnp.stack([k.reshape(B, Kh * Dh), v.reshape(B, Kh * Dh)])
    data = paged_token_write(data, layer, rows.astype(data.dtype), slots,
                             **flags)
    NB, bs = data.shape[2], data.shape[3]
    k_pages = data[0, layer].reshape(NB, bs, Kh, Dh)
    v_pages = data[1, layer].reshape(NB, bs, Kh, Dh)
    o = paged_attention(q[:, 0].astype(k_pages.dtype), k_pages, v_pages,
                        tables, lens + 1, window=window, **flags)
    o = o.reshape(B, 1, H * Dh).astype(x.dtype)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], data


def decode_step_paged(cfg: ModelConfig, params, data, ctl, state, lens,
                      token, *, attn_impl: str = "interpret"):
    """One decode step reading/writing device-resident paged caches in place.

    ``data``: {"kv": [T, L_kind, num_blocks+1, bs, width], "mla": ...}
    (either may be absent) — the bulk page storage, *donated* by the caller
    so the kernel's append lands in place.  ``ctl``: matching per-step
    control tensors {"kv": {"tables": [B, P] int32, "slots": [B] int32
    within-plane row slot of the token being appended}, ...}.  ``state``:
    {"layers": [...]} batched per-layer entries for the non-paged state
    (mamba state/conv, whisper cross xk/xv); paged layers carry empty
    dicts.  ``lens``: [B] int32 tokens already cached; ``token``: [B, 1].

    Returns (logits [B, V], {"kv": new data, "mla": new data}, new state).
    With ``ctl["sample"]`` present (see :func:`sample_from_logits`), the
    first element is instead the sampled token ids [B] — sampling fuses
    into the same jitted computation and only [B] ints cross the host
    boundary.  Unlike :func:`decode_step` there is no per-request
    gather/scatter: the cache never leaves the device and grows by exactly
    one row per request.
    """
    flags = paged_impl_flags(attn_impl)
    B = token.shape[0]
    h = params["embed"][token]
    if not cfg.rope_theta:
        pos_b = layers.lengths_vector(lens, B)
        h = h + layers.sinusoidal_positions(pos_b, cfg.d_model, h.dtype)[:, None]
    h = constrain(h, "dp", None, None)

    kv = dict(ctl.get("kv") or {})
    if "kv" in data:
        kv["data"] = data["kv"]
    mla_e = dict(ctl.get("mla") or {})
    if "mla" in data:
        mla_e["data"] = data["mla"]
    new_state = []
    aj = mj = 0  # running index into the attn / mla cache-layer planes
    for i, kind in enumerate(cfg.layer_kinds()):
        p = params["layers"][i]
        ent = state["layers"][i]
        window = cfg.sliding_window if cfg.is_local_layer(i) else 0
        if kind in (MAMBA1, MAMBA2):
            fn = mamba.mamba1_decode if kind == MAMBA1 else mamba.mamba2_decode
            y, (st, conv) = fn(p, rmsnorm(h, p["norm"], cfg.norm_eps), cfg,
                               ent["state"], ent["conv"])
            h = h + y
            new_state.append({"state": st, "conv": conv})
            continue
        if kind == SHARED_ATTN:
            sp = params["shared"]
            a, kv["data"] = _attn_decode_paged(
                sp, rmsnorm(h, p["norm"], cfg.norm_eps), cfg, kv["data"], aj,
                kv["tables"], kv["slots"], lens, 0, flags)
            aj += 1
            h = h + a
            h = h + layers.mlp(sp, rmsnorm(h, sp["norm2"], cfg.norm_eps),
                               cfg.act)
        elif kind in (MLA_MLP, MLA_MOE):
            a, mla_e["data"] = mla.mla_decode_paged(
                p, rmsnorm(h, p["norm1"], cfg.norm_eps), cfg, mla_e["data"],
                mj, mla_e["tables"], mla_e["slots"], lens, **flags)
            mj += 1
            h = h + a
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind,
                        lossless_moe=True)
            h = h + f
        else:  # ATTN_MLP / ATTN_MOE
            a, kv["data"] = _attn_decode_paged(
                p, rmsnorm(h, p["norm1"], cfg.norm_eps), cfg, kv["data"], aj,
                kv["tables"], kv["slots"], lens, window, flags)
            aj += 1
            h = h + a
            if cfg.cross_attention:
                h = h + _cross_decode(p, rmsnorm(h, p["xnorm"], cfg.norm_eps),
                                      cfg, ent)
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind,
                        lossless_moe=True)
            h = h + f
        new_state.append({})
    logits = _logits(cfg, params, h[:, 0])
    out = logits if ctl.get("sample") is None \
        else sample_from_logits(logits, ctl["sample"])
    new_paged = {}
    if "data" in kv:
        new_paged["kv"] = kv["data"]
    if "data" in mla_e:
        new_paged["mla"] = mla_e["data"]
    return out, new_paged, {"layers": new_state}


# ---------------------------------------------------------------------------
# chunked prefill (paper §3.2/§4.2): extend a cache prefix by a token chunk
# ---------------------------------------------------------------------------
def _attn_chunk(p, x, cfg, prior_k, prior_v, offset, window):
    """x: [B, C, d] chunk; prior_k/v: [B, P, kv_dim].  Returns out + chunk kv."""
    B, C, _ = x.shape
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = offset + jnp.arange(C)
    q = (x @ p["wq"]).reshape(B, C, H, Dh)
    k = (x @ p["wk"]).reshape(B, C, Kh, Dh)
    v = (x @ p["wv"]).reshape(B, C, Kh, Dh)
    if cfg.rope_theta:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    P_len = prior_k.shape[1]
    k_full = jnp.concatenate([prior_k.reshape(B, P_len, Kh, Dh), k], axis=1)
    v_full = jnp.concatenate([prior_v.reshape(B, P_len, Kh, Dh), v], axis=1)
    o = layers.blockwise_attention(q, k_full, v_full, causal=True,
                                   window=window, kv_offset=P_len)
    o = o.reshape(B, C, H * Dh)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], (k.reshape(B, C, Kh * Dh), v.reshape(B, C, Kh * Dh))


def prefill_chunk(cfg: ModelConfig, params, tokens, prior, offset, *,
                  enc_out=None, media_emb=None):
    """Process one prefill chunk against an existing cache prefix.

    tokens: [B, C] (or None if the chunk is pure media); ``prior``: dict
    {"layers": [per-layer prefix entries]} with seq-like entries length P =
    offset tokens; mamba entries carry (state, conv).  Returns
    (last-token logits, chunk cache entries to append, new mamba states).
    """
    if media_emb is not None:
        h = media_emb
        if tokens is not None:
            h = jnp.concatenate([h, params["embed"][tokens]], axis=1)
    else:
        h = params["embed"][tokens]
    C = h.shape[1]
    if not cfg.rope_theta:
        h = h + layers.sinusoidal_positions(offset + jnp.arange(C),
                                            cfg.d_model, h.dtype)
    positions = offset + jnp.arange(C)

    new_entries = []
    for i, kind in enumerate(cfg.layer_kinds()):
        p = params["layers"][i]
        ent = prior["layers"][i]
        window = cfg.sliding_window if cfg.is_local_layer(i) else 0
        if kind in (MAMBA1, MAMBA2):
            fn = mamba.mamba1_seq if kind == MAMBA1 else mamba.mamba2_seq
            y, (state, conv) = fn(p, rmsnorm(h, p["norm"], cfg.norm_eps), cfg,
                                  ent.get("state"), ent.get("conv"))
            h = h + y
            new_entries.append({"state": state, "conv": conv})
        elif kind == SHARED_ATTN:
            sp = params["shared"]
            x_in = rmsnorm(h, p["norm"], cfg.norm_eps)
            a, (k, v) = _attn_chunk(sp, x_in, cfg, ent["k"], ent["v"],
                                    offset, 0)
            h = h + a
            h = h + layers.mlp(sp, rmsnorm(h, sp["norm2"], cfg.norm_eps),
                               cfg.act)
            new_entries.append({"k": k, "v": v})
        elif kind in (MLA_MLP, MLA_MOE):
            x_in = rmsnorm(h, p["norm1"], cfg.norm_eps)
            a, (ckv, krope) = mla.mla_chunk(p, x_in, cfg, ent["ckv"],
                                            ent["krope"], offset)
            h = h + a
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind)
            h = h + f
            new_entries.append({"ckv": ckv, "krope": krope})
        else:
            x_in = rmsnorm(h, p["norm1"], cfg.norm_eps)
            a, (k, v) = _attn_chunk(p, x_in, cfg, ent["k"], ent["v"],
                                    offset, window)
            h = h + a
            new_ent = {"k": k, "v": v}
            if cfg.cross_attention:
                if "xk" in ent and ent["xk"] is not None:
                    xk, xv = ent["xk"], ent["xv"]
                    B = h.shape[0]
                    Kh, Dh = cfg.num_kv_heads, cfg.head_dim
                    q = (rmsnorm(h, p["xnorm"], cfg.norm_eps) @ p["xq"]) \
                        .reshape(B, C, cfg.num_heads, Dh)
                    T = xk.shape[1]
                    o = layers.blockwise_attention(
                        q, xk.reshape(B, T, Kh, Dh), xv.reshape(B, T, Kh, Dh),
                        causal=False)
                    h = h + o.reshape(B, C, -1) @ p["xo"]
                else:
                    c, (xk, xv) = _cross_full(
                        p, rmsnorm(h, p["xnorm"], cfg.norm_eps), enc_out, cfg)
                    h = h + c
                    new_ent.update({"xk": xk, "xv": xv})
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind)
            h = h + f
            new_entries.append(new_ent)
    logits = _logits(cfg, params, h[:, -1])
    return logits, {"layers": new_entries}


def empty_prior(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Zero-length cache prefix for the first prefill chunk."""
    out = []
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind in (MAMBA1, MAMBA2):
            out.append({"state": None, "conv": None})
        elif kind in (MLA_MLP, MLA_MOE):
            out.append({"ckv": jnp.zeros((batch, 0, cfg.kv_lora_rank), dtype),
                        "krope": jnp.zeros((batch, 0, cfg.qk_rope_head_dim),
                                           dtype)})
        else:
            kvd = cfg.num_kv_heads * cfg.head_dim
            out.append({"k": jnp.zeros((batch, 0, kvd), dtype),
                        "v": jnp.zeros((batch, 0, kvd), dtype)})
    return {"layers": out}


def extend_prior(cfg: ModelConfig, prior, chunk_entries):
    """Append a chunk's cache entries onto the prefix (engine bookkeeping)."""
    out = []
    for kind, old, new in zip(cfg.layer_kinds(), prior["layers"],
                              chunk_entries["layers"]):
        if kind in (MAMBA1, MAMBA2):
            out.append(new)  # state replaces
            continue
        ent = {}
        for name in old.keys() | new.keys():
            if name in ("xk", "xv"):
                ent[name] = new.get(name, old.get(name))
            else:
                parts = [x for x in (old.get(name), new.get(name))
                         if x is not None and x.shape[1] > 0]
                ent[name] = jnp.concatenate(parts, axis=1) if len(parts) > 1 \
                    else (parts[0] if parts else old.get(name))
        out.append(ent)
    return {"layers": out}


# ---------------------------------------------------------------------------
# batched chunked prefill over device-resident paged caches (DESIGN.md §12)
# ---------------------------------------------------------------------------
def _attn_chunk_paged(p, x, cfg, data, layer, tables, slots, ctx_lens,
                      window, flags):
    """Chunked-prefill dense attention against the paged KV store: write the
    chunk's K/V rows with one fused launch, then attend the chunk's queries
    through the chunked paged-attention kernel (chunk-causal over pages)."""
    from repro.kernels.cache_write.ops import paged_chunk_write
    from repro.kernels.paged_attention.ops import paged_prefill_attention

    B, C, _ = x.shape
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = ctx_lens[:, None] + jnp.arange(C)                  # [B, C]
    q = (x @ p["wq"]).reshape(B, C, H, Dh)
    k = (x @ p["wk"]).reshape(B, C, Kh, Dh)
    v = (x @ p["wv"]).reshape(B, C, Kh, Dh)
    if cfg.rope_theta:
        q = layers.rope(q, pos, cfg.rope_theta)
        k = layers.rope(k, pos, cfg.rope_theta)
    rows = jnp.stack([k.reshape(B, C, Kh * Dh), v.reshape(B, C, Kh * Dh)])
    data = paged_chunk_write(data, layer, rows.astype(data.dtype), slots,
                             **flags)
    NB, bs = data.shape[2], data.shape[3]
    k_pages = data[0, layer].reshape(NB, bs, Kh, Dh)
    v_pages = data[1, layer].reshape(NB, bs, Kh, Dh)
    o = paged_prefill_attention(q.astype(k_pages.dtype), k_pages, v_pages,
                                tables, ctx_lens, window=window, **flags)
    o = o.reshape(B, C, H * Dh).astype(x.dtype)
    o = constrain(o, "dp", None, "model")
    return o @ p["wo"], data


def _cross_chunk(p, x, enc_out, cfg):
    """Batched cross-attention for a prefill chunk; returns (out, (xk, xv)).
    Recomputed from ``enc_out`` every chunk — deterministic in the encoder
    output, so recomputation keeps the batched step branch-free when the
    batch mixes first and later chunks."""
    B, C, _ = x.shape
    H, Kh, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    T = enc_out.shape[1]
    q = (x @ p["xq"]).reshape(B, C, H, Dh)
    k = (enc_out.astype(x.dtype) @ p["xk"]).reshape(B, T, Kh, Dh)
    v = (enc_out.astype(x.dtype) @ p["xv"]).reshape(B, T, Kh, Dh)
    o = layers.blockwise_attention(q, k, v, causal=False)
    o = o.reshape(B, C, H * Dh)
    o = constrain(o, "dp", None, "model")
    return o @ p["xo"], (k.reshape(B, T, Kh * Dh), v.reshape(B, T, Kh * Dh))


def prefill_chunk_paged(cfg: ModelConfig, params, data, ctl, state, ctx_lens,
                        tokens, *, attn_impl: str = "interpret"):
    """One batched prefill chunk reading/writing device paged caches in place.

    The prefill analogue of :func:`decode_step_paged`: C tokens per request
    for a whole batch of requests in ONE jitted computation — no host
    gather of the prior context, no numpy round-trip of the chunk's K/V.

    ``data``: {"kv": [2, L_attn, NB+1, bs, w], "mla": ...} bulk page pools,
    *donated* by the caller.  ``ctl``: per-chunk control tensors —
    {"kv"|"mla": {"tables": [B, P] int32, "slots": [B, C] int32 within-plane
    row slots of the chunk tokens (padded positions point at scratch)},
    "img": {"slots": [B, C] int32 image-cache row per media position or -1,
    "pages": image page pool} (optional), "mask": [B, C] bool valid chunk
    positions, "last": [B] int32 index of each request's last valid
    position}.  ``state``: {"layers": [...batched mamba state/conv...],
    "enc_out": [B, T, d] (cross-attention archs)}.  ``ctx_lens``: [B] int32
    tokens already cached; ``tokens``: [B, C] int32 (0 at media positions —
    media embeddings are read straight off the image-cache pages).

    Returns (last-token logits [B, V], new paged data, new state with
    per-layer mamba state/conv and cross xk/xv for host bookkeeping).
    With ``ctl["sample"]`` present the first element is the sampled
    next-token ids [B] (see :func:`sample_from_logits`) — this is how a
    request's *first* token is drawn without shipping logits to the host.
    """
    flags = paged_impl_flags(attn_impl)
    B, C = tokens.shape
    h = params["embed"][tokens]
    img = ctl.get("img")
    if img is not None:
        # media positions read their embedding rows off the image-cache
        # pages on device (no host gather of media embeddings)
        img_flat = img["pages"][0, 0].reshape(-1, img["pages"].shape[-1])
        islots = img["slots"]
        media_h = img_flat[jnp.maximum(islots, 0)]
        h = jnp.where((islots >= 0)[..., None], media_h.astype(h.dtype), h)
    if not cfg.rope_theta:
        pos = (ctx_lens[:, None] + jnp.arange(C)).reshape(-1)
        h = h + layers.sinusoidal_positions(pos, cfg.d_model,
                                            h.dtype).reshape(B, C, -1)
    h = constrain(h, "dp", None, None)

    mask = ctl["mask"]
    kv = dict(ctl.get("kv") or {})
    if "kv" in data:
        kv["data"] = data["kv"]
    mla_e = dict(ctl.get("mla") or {})
    if "mla" in data:
        mla_e["data"] = data["mla"]
    enc_out = state.get("enc_out")
    new_state = []
    aj = mj = 0  # running index into the attn / mla cache-layer planes
    for i, kind in enumerate(cfg.layer_kinds()):
        p = params["layers"][i]
        ent = state["layers"][i]
        window = cfg.sliding_window if cfg.is_local_layer(i) else 0
        if kind in (MAMBA1, MAMBA2):
            fn = mamba.mamba1_seq if kind == MAMBA1 else mamba.mamba2_seq
            y, (st, conv) = fn(p, rmsnorm(h, p["norm"], cfg.norm_eps), cfg,
                               ent["state"], ent["conv"], mask=mask)
            h = h + y
            new_state.append({"state": st, "conv": conv})
            continue
        if kind == SHARED_ATTN:
            sp = params["shared"]
            a, kv["data"] = _attn_chunk_paged(
                sp, rmsnorm(h, p["norm"], cfg.norm_eps), cfg, kv["data"], aj,
                kv["tables"], kv["slots"], ctx_lens, 0, flags)
            aj += 1
            h = h + a
            h = h + layers.mlp(sp, rmsnorm(h, sp["norm2"], cfg.norm_eps),
                               cfg.act)
            new_state.append({})
        elif kind in (MLA_MLP, MLA_MOE):
            a, mla_e["data"] = mla.mla_chunk_paged(
                p, rmsnorm(h, p["norm1"], cfg.norm_eps), cfg, mla_e["data"],
                mj, mla_e["tables"], mla_e["slots"], ctx_lens, **flags)
            mj += 1
            h = h + a
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind,
                        lossless_moe=True)
            h = h + f
            new_state.append({})
        else:  # ATTN_MLP / ATTN_MOE
            a, kv["data"] = _attn_chunk_paged(
                p, rmsnorm(h, p["norm1"], cfg.norm_eps), cfg, kv["data"], aj,
                kv["tables"], kv["slots"], ctx_lens, window, flags)
            aj += 1
            h = h + a
            ent2 = {}
            if cfg.cross_attention:
                c, (xk, xv) = _cross_chunk(
                    p, rmsnorm(h, p["xnorm"], cfg.norm_eps), enc_out, cfg)
                h = h + c
                ent2 = {"xk": xk, "xv": xv}
            f, _ = _ffn(p, rmsnorm(h, p["norm2"], cfg.norm_eps), cfg, kind,
                        lossless_moe=True)
            h = h + f
            new_state.append(ent2)
    h_last = jnp.take_along_axis(h, ctl["last"][:, None, None], axis=1)[:, 0]
    logits = _logits(cfg, params, h_last)
    if ctl.get("sample") is not None:
        logits = sample_from_logits(logits, ctl["sample"])
    new_paged = {}
    if "data" in kv:
        new_paged["kv"] = kv["data"]
    if "data" in mla_e:
        new_paged["mla"] = mla_e["data"]
    return logits, new_paged, {"layers": new_state}
