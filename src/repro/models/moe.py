"""Mixture-of-Experts FFN (TPU/GSPMD-friendly, expert-parallel).

Top-k token-choice routing with a per-expert capacity.  Dispatch/combine
use scatter-add / gather (linear in tokens) instead of the classic
[T, E, C] dispatch einsum, which is quadratic in sequence length and
dominates expert compute at 32k tokens.  Expert weights shard over the
"model" mesh axis (expert parallelism); shared experts (DeepSeek-V2) are
plain dense MLPs added on top.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.sharding import constrain


def init_moe(key, cfg, dtype):
    d, ff, E = cfg.d_model, (cfg.moe_d_ff or cfg.d_ff), cfg.num_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": layers.dense_init(ks[0], (d, E), jnp.float32),
        "moe_w_gate": layers.dense_init(ks[1], (E, d, ff), dtype),
        "moe_w_up": layers.dense_init(ks[2], (E, d, ff), dtype),
        "moe_w_down": layers.dense_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.num_shared_experts:
        sff = ff * cfg.num_shared_experts
        p["sh_w_gate"] = layers.dense_init(ks[4], (d, sff), dtype)
        p["sh_w_up"] = layers.dense_init(ks[5], (d, sff), dtype)
        p["sh_w_down"] = layers.dense_init(ks[6], (sff, d), dtype)
    return p


# module toggle for the data-shard-aware dispatch (EXPERIMENTS.md §Perf);
# flipped by the dry-run's --moe-dispatch flag
DATA_SHARDED_DISPATCH = False


def moe_ffn(p, x, cfg, *, lossless: bool = False,
            data_sharded_dispatch=None):
    """x: [B, S, d] -> ([B, S, d], aux load-balance loss).

    ``lossless`` uses capacity == T (no token ever dropped) — used by the
    decode step, where T = batch is small and dropping would corrupt
    generation.  Otherwise capacity = cfg.moe_capacity_factor * T * k / E
    (Switch-style dropping, faithful for training).
    """
    if data_sharded_dispatch is None:
        data_sharded_dispatch = DATA_SHARDED_DISPATCH
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = xt.astype(jnp.float32) @ p["router"]              # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    if lossless:
        capacity = T
    else:
        capacity = min(max(1, int(cfg.moe_capacity_factor * T * k / E)), T)

    # queue position of each (token, slot) within its expert — sort-based
    # ranking, O(T*k) memory (a cumsum over a [T*k, E] one-hot would
    # materialize terabytes at 1M tokens x 160 experts)
    e_flat = expert_ids.reshape(T * k)
    order = jnp.argsort(e_flat, stable=True)       # stable = arrival order
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    ranks = jnp.arange(T * k) - starts[e_flat[order]]
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(ranks.astype(jnp.int32))
    keep = pos < capacity
    p_flat = jnp.where(keep, pos, capacity)                    # C = overflow row

    # Switch-style aux load-balance loss (counts-based: no [T,k,E] one-hot)
    me = jnp.mean(probs, axis=0)
    ce = counts.astype(jnp.float32) / (T * k)
    aux_loss = E * jnp.sum(me * jax.lax.stop_gradient(ce) +
                           jax.lax.stop_gradient(me) * ce) * 0.5

    # dispatch: scatter tokens into per-expert buffers [E, C+1, d]
    xt_rep = jnp.repeat(xt[:, None], k, axis=1).reshape(T * k, d)
    from repro.models.sharding import current_mesh
    mesh = current_mesh()
    n_ds = mesh.shape.get("data", 1) if mesh is not None else 1
    if data_sharded_dispatch and n_ds > 1 and T % n_ds == 0:
        # Beyond-paper optimization (see EXPERIMENTS.md §Perf): give the
        # capacity buffer a leading data-shard dim and rank tokens within
        # (expert, shard) so every scatter update stays on its own data
        # shard — GSPMD then avoids all-gathering the [T*k, d] dispatch
        # tokens across the data axis (64 GB/layer for DeepSeek train_4k).
        T_loc = T // n_ds
        cap_l = min(max(1, capacity // n_ds + 1), T_loc)
        shard_id = (jnp.arange(T * k) // (T_loc * k)).astype(jnp.int32)
        # rank within (expert, shard): sort by (expert, shard)
        key2 = e_flat * n_ds + shard_id
        order2 = jnp.argsort(key2, stable=True)
        counts2 = jnp.bincount(key2, length=E * n_ds)
        starts2 = jnp.cumsum(counts2) - counts2
        ranks2 = jnp.arange(T * k) - starts2[key2[order2]]
        pos_l = jnp.zeros((T * k,), jnp.int32).at[order2].set(
            ranks2.astype(jnp.int32))
        pos_l = jnp.where(pos_l < cap_l, pos_l, cap_l)
        buf = jnp.zeros((E, n_ds, cap_l + 1, d), x.dtype)
        buf = buf.at[e_flat, shard_id, pos_l].add(xt_rep)
        # constrain the scatter RESULT: without this GSPMD materializes the
        # scatter with a replicated output and all-gathers it across data
        # (~288 GB/layer measured — see EXPERIMENTS.md §Perf pair 3)
        buf = constrain(buf, "model", "data", None, None)
        xe = buf[:, :, :cap_l].reshape(E, n_ds * cap_l, d)
        gather_idx = (shard_id, pos_l)
    else:
        buf = jnp.zeros((E, capacity + 1, d), x.dtype)
        buf = buf.at[e_flat, p_flat].add(xt_rep)
        xe = buf[:, :capacity]                                 # [E, C, d]
        gather_idx = None
    xe = constrain(xe, "model", None, None)

    a = layers.act_fn(cfg.act)
    h = a(jnp.einsum("ecd,edf->ecf", xe, p["moe_w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["moe_w_up"])
    h = constrain(h, "model", None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, p["moe_w_down"])        # [E, C, d]
    ye = constrain(ye, "model", None, None)

    # combine: gather each (token, slot)'s output and mix by gate value
    if gather_idx is not None:
        shard_id, pos_l = gather_idx
        cap_l = ye.shape[1] // n_ds
        ye4 = jnp.concatenate(
            [ye.reshape(E, n_ds, cap_l, d),
             jnp.zeros((E, n_ds, 1, d), ye.dtype)], axis=2)
        y_tok = ye4[e_flat, shard_id, pos_l].reshape(T, k, d)
    else:
        ye_pad = jnp.concatenate([ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1)
        y_tok = ye_pad[e_flat, p_flat].reshape(T, k, d)
    out = jnp.sum(y_tok * gate_vals[..., None].astype(ye.dtype), axis=1)
    out = out.astype(x.dtype)

    if "sh_w_gate" in p:
        out = out + layers.gated_mlp(
            {"w_gate": p["sh_w_gate"], "w_up": p["sh_w_up"],
             "w_down": p["sh_w_down"]}, xt, cfg.act)
    return out.reshape(B, S, d), aux_loss


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (EXPERIMENTS.md §Perf pair 3 fix)
# ---------------------------------------------------------------------------
# Plain-GSPMD capacity dispatch pays a cross-shard gather/reduce because the
# SPMD scatter partitioner cannot prove update locality (two refuted
# iterations recorded in EXPERIMENTS.md).  Here the communication is
# explicit: per-device routing -> all_to_all over the "model" axis (tokens
# to their expert's owner) -> local scatter + expert matmuls -> all_to_all
# back -> local combine.
MOE_SHARDMAP = False


def _local_rank(ids, n_bins):
    """Stable rank of each element within its bin; O(T) memory."""
    order = jnp.argsort(ids, stable=True)
    counts = jnp.bincount(ids, length=n_bins)
    starts = jnp.cumsum(counts) - counts
    ranks = jnp.arange(ids.shape[0]) - starts[ids[order]]
    return jnp.zeros_like(ids).at[order].set(ranks.astype(ids.dtype))


def moe_ffn_shardmap(p, x, cfg, mesh):
    """x: [B, S, d] sharded (dp, "model", None).  Returns (out, aux)."""
    import jax as _jax
    from jax.sharding import PartitionSpec as P

    E, k = cfg.num_experts, cfg.experts_per_token
    M = mesh.shape["model"]
    E_loc = E // M
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    all_axes = tuple(mesh.axis_names)

    def body(router, wg, wu, wd, xb):
        Bl, Sl, d = xb.shape
        T = Bl * Sl
        xt = xb.reshape(T, d)
        logits = xt.astype(jnp.float32) @ router
        probs = _jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = _jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        e_flat = expert_ids.reshape(T * k)
        g_flat = gate_vals.reshape(T * k)
        src = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        dest = e_flat // E_loc                          # target model shard
        e_local = e_flat % E_loc

        C_s = min(max(1, int(cfg.moe_capacity_factor * T * k / M)), T * k)
        pos = _local_rank(dest, M)
        ok = pos < C_s
        slot = jnp.where(ok, pos, C_s)

        def scat(values, fill):
            buf = jnp.full((M, C_s + 1) + values.shape[1:], fill,
                           values.dtype)
            return buf.at[dest, slot].set(values)[:, :C_s]

        send_x = scat(xt[src], 0.0)                     # [M, C_s, d]
        send_e = scat(e_local, E_loc)                   # E_loc = invalid
        send_s = scat(src, -1)

        recv_x = _jax.lax.all_to_all(send_x, "model", 0, 0, tiled=False)
        recv_e = _jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)
        recv_s = _jax.lax.all_to_all(send_s, "model", 0, 0, tiled=False)
        # [M, C_s, ...] -> flat local work queue
        R = M * C_s
        rx = recv_x.reshape(R, d)
        re = recv_e.reshape(R)
        valid = re < E_loc
        re_c = jnp.where(valid, re, E_loc)

        C_l = min(max(1, int(cfg.moe_capacity_factor * R / max(E_loc, 1))), R)
        pos_l = _local_rank(re_c.astype(jnp.int32), E_loc + 1)
        ok_l = valid & (pos_l < C_l)
        slot_l = jnp.where(ok_l, pos_l, C_l)
        buf = jnp.zeros((E_loc, C_l + 1, d), xb.dtype)
        buf = buf.at[re_c, slot_l].set(rx.astype(xb.dtype))
        xe = buf[:, :C_l]

        a = layers.act_fn(cfg.act)
        h = a(jnp.einsum("ecd,edf->ecf", xe, wg))
        h = h * jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", h, wd)          # [E_loc, C_l, d]
        ye_pad = jnp.concatenate([ye, jnp.zeros((E_loc, 1, d), ye.dtype)], 1)
        back = ye_pad[re_c, slot_l]                     # [R, d]
        back = jnp.where(ok_l[:, None], back, 0.0)
        back = back.reshape(M, C_s, d)
        ret = _jax.lax.all_to_all(back, "model", 0, 0, tiled=False)
        ret = ret.reshape(M * C_s, d)                   # rows align with send

        # combine on the source shard
        contrib = jnp.zeros((T + 1, d), jnp.float32)
        src_pad = scat(src, T)                          # [M, C_s] w/ sentinel
        g_pad = scat(g_flat, 0.0)
        contrib = contrib.at[src_pad.reshape(-1)].add(
            ret.astype(jnp.float32) * g_pad.reshape(-1, 1))
        out = contrib[:T].astype(xb.dtype).reshape(Bl, Sl, d)

        # aux load-balance loss (global means via psum-mean)
        me = _jax.lax.pmean(jnp.mean(probs, axis=0), all_axes)
        ce = _jax.lax.pmean(
            jnp.bincount(e_flat, length=E).astype(jnp.float32) / (T * k),
            all_axes)
        aux = E * jnp.sum(me * ce)
        return out, aux

    in_specs = (P(None, None), P("model", None, None),
                P("model", None, None), P("model", None, None),
                P(dp, "model", None))
    out_specs = (P(dp, "model", None), P())
    if hasattr(_jax, "shard_map"):  # jax >= 0.6
        fn = _jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)
    else:  # older jax: experimental module, check flag named check_rep
        from jax.experimental.shard_map import shard_map as _shard_map
        fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    out, aux = fn(p["router"], p["moe_w_gate"], p["moe_w_up"],
                  p["moe_w_down"], x)

    if "sh_w_gate" in p:
        B, S, d = x.shape
        sh = layers.gated_mlp(
            {"w_gate": p["sh_w_gate"], "w_up": p["sh_w_up"],
             "w_down": p["sh_w_down"]}, x.reshape(B * S, d), cfg.act)
        out = out + sh.reshape(B, S, d)
    return out, aux
