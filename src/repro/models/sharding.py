"""Sharding policy: mesh context + parameter partition rules.

The model code calls :func:`constrain` on activations; outside of a mesh
context (CPU smoke tests) it is a no-op, so the same model code runs both
single-device and under the production mesh.
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def current_mesh() -> Optional[Mesh]:
    return _MESH


@contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def dp_axes() -> tuple:
    """Data-parallel axes present in the active mesh ((pod, data) or (data,))."""
    if _MESH is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in _MESH.axis_names)


def _filter_spec(spec: tuple) -> P:
    """Drop axis names not present in the active mesh; keep dims aligned."""
    names = set(_MESH.axis_names)
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, tuple):
            kept = tuple(a for a in s if a in names)
            out.append(kept if kept else None)
        else:
            out.append(s if s in names else None)
    return P(*out)


def constrain(x, *spec):
    """with_sharding_constraint if a mesh is active, identity otherwise.

    ``"dp"`` in a spec expands to the data-parallel axes tuple.
    """
    if _MESH is None:
        return x
    spec = tuple(dp_axes() if s == "dp" else s for s in spec)
    ns = NamedSharding(_MESH, _filter_spec(spec))
    return jax.lax.with_sharding_constraint(x, ns)


def divisible(dim: int, axis: str) -> bool:
    if _MESH is None or axis not in _MESH.axis_names:
        return False
    return dim % _MESH.shape[axis] == 0


def _expert2d_spec(path, spec, data_axes):
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = p.key
            break
    da = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    if name in ("moe_w_gate", "moe_w_up"):
        return P("model", None, da)
    if name == "moe_w_down":
        return P("model", da, None)
    return spec


# ---------------------------------------------------------------------------
# Parameter partition rules (matched on the param's key name)
# ---------------------------------------------------------------------------
# Each rule: leaf-name -> spec builder given array ndim.  Specs use logical
# axes; "model" shards tensor-parallel dims, data axes never shard params.
_COL = P(None, "model")          # [in, out_sharded]
_ROW = P("model", None)          # [in_sharded, out]
_EXP_COL = P("model", None, None)  # [experts_sharded, in, out]

PARAM_RULES: dict[str, P] = {
    # embeddings / head
    "embed": P("model", None),          # vocab-sharded
    "lm_head": _COL,
    "media_proj_w1": _COL,
    "media_proj_w2": _ROW,
    # attention
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "bq": P("model"), "bk": P("model"), "bv": P("model"), "bo": P(None),
    # cross attention (whisper)
    "xq": _COL, "xk": _COL, "xv": _COL, "xo": _ROW,
    # MLP
    "w_gate": _COL, "w_up": _COL, "w_down": _ROW,
    # MoE
    "router": P(None, None),
    "moe_w_gate": _EXP_COL, "moe_w_up": _EXP_COL, "moe_w_down": _EXP_COL,
    "sh_w_gate": _COL, "sh_w_up": _COL, "sh_w_down": _ROW,
    # MLA
    "q_a": P(None, None), "q_b": _COL,
    "kv_a": P(None, None), "kv_b": _COL,
    # Mamba
    "in_proj": _COL, "out_proj": _ROW,
    "conv_w": P(None, "model"), "conv_b": P("model"),
    "x_proj": _ROW, "dt_proj": _COL,
    "dt_bias": P("model"), "A_log": P("model"), "D": P("model"),
    "A_log2": P("model"), "D2": P("model"), "dt_bias2": P("model"),
    "ssm_norm": P("model"),
}
_REPLICATED_HINTS = ("norm", "scale", "bias", "pos")


def spec_for(name: str, arr) -> P:
    ndim = getattr(arr, "ndim", len(getattr(arr, "shape", ())))
    if name in PARAM_RULES:
        spec = PARAM_RULES[name]
        if len(spec) > ndim:  # e.g. bias rules vs scalar
            return P()
        return spec
    if any(h in name for h in _REPLICATED_HINTS):
        return P()
    return P()


def param_pspecs(params) -> dict:
    """Pytree of PartitionSpecs matching a params pytree (by leaf key name)."""
    def walk(node):
        if isinstance(node, dict):
            return {k: (spec_for(k, v) if not isinstance(v, (dict, list, tuple))
                        else walk(v))
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v) for v in node)
        return P()
    return walk(params)


def param_shardings(mesh: Mesh, params, *, fsdp: bool = False,
                    expert_2d: bool = False) -> dict:
    """NamedShardings for a params pytree.

    ``fsdp=True`` additionally shards each large tensor's biggest free dim
    over the data(-and-pod) axes — ZeRO-style, required for models whose
    params+optimizer exceed HBM under model-parallel sharding alone
    (e.g. DeepSeek-V2-236B training).
    """
    pspecs = param_pspecs(params)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data_sz = 1
    for a in data_axes:
        data_sz *= mesh.shape[a]
    if expert_2d:
        # 2D expert tensor-parallelism for huge-MoE inference: shard the
        # per-expert ffn dim over the data axes (experts stay on "model"),
        # so weights are 256-way resident with NO per-layer gathers — the
        # down-projection contracts a sharded dim (small all-reduce).
        pspecs = jax.tree_util.tree_map_with_path(
            lambda path, s: _expert2d_spec(path, s, data_axes), pspecs,
            is_leaf=lambda n: isinstance(n, P))

    def fix(leaf, spec):
        # drop axes the mesh doesn't have and dims that don't divide
        names = set(mesh.axis_names)
        out = []
        for d, s in enumerate(tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            if s is None or s not in names or leaf.shape[d] % mesh.shape[s] != 0:
                out.append(None)
            else:
                out.append(s)
        if fsdp and leaf.ndim >= 2 and int(np.prod(leaf.shape)) >= (1 << 16):
            free = [d for d in range(leaf.ndim) if out[d] is None]
            free.sort(key=lambda d: -leaf.shape[d])
            for d in free:
                if leaf.shape[d] % data_sz == 0 and data_axes:
                    out[d] = data_axes if len(data_axes) > 1 else data_axes[0]
                    break
                if "data" in mesh.axis_names and \
                        leaf.shape[d] % mesh.shape["data"] == 0:
                    out[d] = "data"
                    break
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, params, pspecs,
                        is_leaf=lambda n: not isinstance(n, (dict, list, tuple)))
