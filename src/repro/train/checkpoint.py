"""Flat-npz checkpointing for params/optimizer pytrees (no orbax here)."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save(path: str, tree) -> None:
    np.savez(path, **_flatten(tree))


def load(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path)

    def rebuild(node, prefix=""):
        if isinstance(node, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(rebuild(v, f"{prefix}{i}/") for i, v in enumerate(node))
        key = prefix.rstrip("/")
        arr = data[key]
        return jax.numpy.asarray(arr).astype(node.dtype)

    return rebuild(like)
