"""Synthetic multimodal LM data pipeline.

Deterministic, seeded batch stream with (a) Zipfian token draws so the loss
has learnable structure, (b) optional media/frames embeddings for VLM/audio
configs, (c) document packing with -1 label padding at boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    doc_len_mean: int = 96       # documents packed into seq_len rows


def _zipf_tokens(rng, n, vocab):
    # zipf over a capped vocab so small smoke vocabs work
    z = rng.zipf(1.3, size=n).astype(np.int64)
    return (z % vocab).astype(np.int32)


def batches(cfg: ModelConfig, data: DataConfig) -> Iterator[dict]:
    rng = np.random.default_rng(data.seed)
    n_media = cfg.media_tokens if cfg.frontend != "none" else 0
    while True:
        B, S = data.batch_size, data.seq_len
        toks = np.empty((B, S), np.int32)
        labels = np.empty((B, S), np.int32)
        for b in range(B):
            row = []
            while len(row) < S:
                L = max(8, int(rng.exponential(data.doc_len_mean)))
                doc = _zipf_tokens(rng, L, cfg.vocab_size)
                # inject learnable bigram structure: even positions echo
                doc[1::2] = (doc[0::2][: len(doc[1::2])] + 1) % cfg.vocab_size
                row.extend(doc.tolist() + [-1])  # -1 marks the boundary
            row = np.array(row[:S], np.int32)
            labels[b] = row
            toks[b] = np.maximum(row, 0)
        batch = {"tokens": toks, "labels": labels}
        if n_media:
            med = rng.standard_normal((B, n_media, cfg.d_model)).astype(np.float32)
            key = "frames" if cfg.frontend == "audio" else "media"
            batch[key] = med * 0.02
        yield batch
