"""AdamW + schedules, pure-pytree (no optax dependency in this container)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"lr": lr, "grad_norm": gnorm}
