"""Training step: next-token CE (+ MoE aux loss), remat'd blocks, AdamW."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.sharding import constrain
from repro.train.optim import AdamWConfig, adamw_update, init_opt_state

AUX_LOSS_WEIGHT = 0.01


def loss_fn(cfg: ModelConfig, params, batch, *, remat: bool = True):
    """batch: {tokens [B,S], labels [B,S], media?, frames?}.

    Loss is next-token CE over the text segment (media prefix positions are
    excluded); labels shifted internally, -1 = padding.
    """
    tokens = batch["tokens"]
    labels = batch.get("labels", tokens)
    logits, _, aux = M.forward(cfg, params, tokens,
                               media=batch.get("media"),
                               frames=batch.get("frames"), remat=remat)
    n_media = logits.shape[1] - tokens.shape[1]
    lg = logits[:, n_media:]
    # predict labels[t+1] from position t
    lg = lg[:, :-1].astype(jnp.float32)
    tgt = labels[:, 1:]
    mask = (tgt >= 0).astype(jnp.float32)
    tgt = jnp.maximum(tgt, 0)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + AUX_LOSS_WEIGHT * aux, {"ce": ce, "aux": aux}


def train_step(cfg: ModelConfig, opt: AdamWConfig, params, opt_state, batch,
               *, remat: bool = True):
    (loss, stats), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, remat=remat), has_aux=True)(params)
    params, opt_state, ostats = adamw_update(opt, params, grads, opt_state)
    return params, opt_state, {"loss": loss, **stats, **ostats}


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, *, remat: bool = True):
    return functools.partial(train_step, cfg, opt, remat=remat)
