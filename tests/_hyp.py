"""Optional-dependency shim for ``hypothesis`` (see README.md).

``from _hyp import given, settings, st`` behaves exactly like the real
``from hypothesis import given, settings, strategies as st`` when
hypothesis is installed.  When it is not, the property-based tests are
collected as skips while the rest of the module still runs — a bare
``import hypothesis`` used to fail all three system test modules at
collection time.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call made at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # plain zero-arg stub (no functools.wraps: pytest would follow
            # __wrapped__ and treat the hypothesis params as fixtures)
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
