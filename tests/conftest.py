import dataclasses

import jax
import numpy as np
import pytest

# NOTE: deliberately no xla_force_host_platform_device_count here — smoke
# tests and benches must see the 1 real device; only launch/dryrun.py forces
# 512 placeholder devices (in its own process).

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def reduced_cfg(arch: str, **overrides):
    from repro.configs import get_config
    cfg = get_config(arch).reduced()
    if cfg.num_experts and "moe_capacity_factor" not in overrides:
        overrides["moe_capacity_factor"] = 16.0  # no drops in tiny tests
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
