import dataclasses

import jax
import numpy as np
import pytest

# NOTE: deliberately no xla_force_host_platform_device_count here — smoke
# tests and benches must see the 1 real device; only launch/dryrun.py forces
# 512 placeholder devices (in its own process).

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def reduced_cfg(arch: str, **overrides):
    from repro.configs import get_config
    cfg = get_config(arch).reduced()
    if cfg.num_experts and "moe_capacity_factor" not in overrides:
        overrides["moe_capacity_factor"] = 16.0  # no drops in tiny tests
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def assert_all_reclaimed(server):
    """Every request retired/aborted leaves the server's cache pools fully
    reclaimed.  Sharing-aware (DESIGN.md §14): refcount-zero blocks may
    legitimately park in the evictable pool (their content stays indexed
    for future prefix hits), so "reclaimed" means free + evictable covers
    the whole pool, every refcount is zero, and no block is double-listed.
    With sharing off this degrades to the strict PR-4 all-free assert."""
    for inst in server.instances:
        assert not inst.running and not inst.waiting
        for c in (inst.caches.kv, inst.caches.mla, inst.caches.img):
            if c is None:
                continue
            assert not c.tables and not c.lengths, \
                f"inst {inst.iid}: live tables remain: {c.tables}"
            free = set(c.allocator.free)
            assert len(free) == c.allocator.n_free, "duplicate free-list entry"
            assert free.isdisjoint(c.evictable), "block both free and evictable"
            assert c.allocator.n_free + len(c.evictable) \
                == c.allocator.num_blocks, \
                f"inst {inst.iid}: {c.allocator.n_free} free + " \
                f"{len(c.evictable)} evictable of {c.allocator.num_blocks}"
            assert all(rc == 0 for rc in c.refcount), \
                f"inst {inst.iid}: nonzero refcounts {c.refcount}"
            assert set(c.evictable) <= set(c.block_hash), \
                "evictable block missing from the prefix index"
        assert not inst.caches.states.store
