"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each assigned arch, run one forward + one train step on
CPU, assert output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.train.optim import AdamWConfig, init_opt_state
from repro.train.train import train_step

from conftest import reduced_cfg


def _inputs(cfg, key, B=2, S=24):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend == "vision":
        kw["media"] = jax.random.normal(key, (B, cfg.media_tokens, cfg.d_model)) * 0.1
    if cfg.frontend == "audio":
        kw["frames"] = jax.random.normal(key, (B, cfg.media_tokens, cfg.d_model)) * 0.1
    return tokens, kw


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward(arch):
    cfg = reduced_cfg(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 6
    assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tokens, kw = _inputs(cfg, key)
    logits, _, aux = M.forward(cfg, params, tokens, **kw)
    S_tot = tokens.shape[1] + (cfg.media_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, S_tot, cfg.vocab_size)
    assert not jnp.isnan(logits).any()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced_cfg(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    tokens, kw = _inputs(cfg, key, B=2, S=16)
    batch = {"tokens": tokens, "labels": tokens, **kw}
    opt = AdamWConfig(warmup_steps=1, total_steps=10)
    state = init_opt_state(params)
    params2, state2, stats = train_step(cfg, opt, params, state, batch,
                                        remat=True)
    assert jnp.isfinite(stats["loss"])
    assert int(state2["step"]) == 1
    # params actually changed
    changed = any(float(jnp.max(jnp.abs(a - b))) > 0
                  for a, b in zip(jax.tree.leaves(params2),
                                  jax.tree.leaves(params)))
    assert changed


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    """Prefill + one decode step == full forward on the same tokens."""
    cfg = reduced_cfg(arch)
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    B, S = 2, 21
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    _, kw = _inputs(cfg, key, B=B)
    full, _, _ = M.forward(cfg, params, tokens, **kw)
    ref = full[:, -1]
    n_media = cfg.media_tokens if cfg.frontend == "vision" else 0
    last, pc = M.prefill(cfg, params, tokens[:, :S], **kw)
    S_tot = S + n_media
    cache = M.build_cache_from_prefill(cfg, pc, max_len=S_tot + 4)
    lg, _ = M.decode_step(cfg, params, cache, jnp.int32(S_tot),
                          tokens[:, S:S + 1])
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(lg - ref))) / scale < 2e-3
