"""Disaggregation autotuner (DESIGN.md §7): enumeration, heterogeneous
configs, cost-model bound soundness, and argmax preservation vs the
exhaustive search."""
import pytest

from repro.configs import get_config
from repro.core.autotuner import (_bisect_goodput, _SimCache,
                                  autotune_disaggregation,
                                  enumerate_hetero_disaggs,
                                  upper_bound_goodput, workload_stats)
from repro.core.costmodel import H800, L40S
from repro.core.hybrid_epd import (enumerate_disaggs, search_disaggregation,
                                   simulate_once)
from repro.core.request import Stage
from repro.core.simulator import Cluster, DisaggConfig, RoleSpec
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests, slo_for

MODEL = "llava-1.5-7b"
CFG = get_config(MODEL)
PROFILE = PROFILES["textcaps"]
SLO = slo_for(MODEL, "textcaps")
IMG = IMAGE_TOKENS[MODEL]

HETERO = DisaggConfig({"EP": RoleSpec(2, hw=H800),
                       "D": RoleSpec(2, hw=L40S)})


# ---------------------------------------------------------------------------
# enumeration + DisaggConfig naming
# ---------------------------------------------------------------------------
def test_enumerate_disaggs_grid():
    cands = enumerate_disaggs(8)
    names = [c.name for c in cands]
    assert len(names) == len(set(names))
    # aggregated + 2-way ratios + full 3-way grid
    assert "8EPD" in names and "4EP+4D" in names and "1E+3P+4D" in names
    assert all(sum(s.count for _, s in c.roles) == 8 for c in cands)
    methods = {c.method for c in cands}
    assert methods == {"EPD", "D+EP", "ED+P", "D+E+P"}
    # text-only grids never contain encode-capable roles
    assert all("E" not in c.method
               for c in enumerate_disaggs(8, multimodal=False))


def test_disagg_name_and_method():
    dc = DisaggConfig({"EP": 2, "D": 6})
    assert dc.name == "2EP+6D" and dc.method == "D+EP"
    assert not dc.heterogeneous and dc.total_instances == 8
    assert HETERO.name == "2EP@H800+2D@L40S"
    assert HETERO.method == "D+EP"
    assert HETERO.heterogeneous and HETERO.total_instances == 4
    # zero-count roles drop out of both name and method
    assert DisaggConfig({"E": 0, "PD": 4}).method == "PD"


def test_enumerate_hetero_disaggs():
    cands = enumerate_hetero_disaggs([(H800, 2), (L40S, 2)])
    names = [c.name for c in cands]
    assert len(names) == len(set(names))
    assert all(c.heterogeneous for c in cands)
    assert all(c.total_instances == 4 for c in cands)
    # every role group is pinned to exactly one pool's hardware
    for c in cands:
        for _, s in c.roles:
            assert s.hw in (H800, L40S)
    # both pool assignments of the 2-group method appear
    assert "2EP@H800+2D@L40S" in names and "2D@H800+2EP@L40S" in names


# ---------------------------------------------------------------------------
# heterogeneous cluster construction + routing
# ---------------------------------------------------------------------------
def test_hetero_cluster_per_instance_resolution():
    cl = Cluster(CFG, H800, HETERO, SLO)
    by_role = {}
    for inst in cl.instances:
        by_role.setdefault(inst.role_name, []).append(inst)
    assert [i.hw.name for i in by_role["EP"]] == ["H800", "H800"]
    assert [i.hw.name for i in by_role["D"]] == ["L40S", "L40S"]
    # budgets resolve per hardware profile, not per cluster
    assert by_role["EP"][0].budgets != by_role["D"][0].budgets


def test_hetero_routing_only_capable_instances():
    cl = Cluster(CFG, H800, HETERO, SLO)
    reqs = make_requests(PROFILE, rate=8.0, n=12,
                         image_tokens_per_image=IMG, slo=SLO, seed=1)
    for r in reqs:
        for stage in (Stage.ENCODE, Stage.PREFILL, Stage.DECODE):
            inst = cl.route(r, stage)
            assert stage in inst.role
            # encode/prefill must land on the H800 group, decode on L40S
            assert inst.hw.name == ("L40S" if stage == Stage.DECODE
                                    else "H800")
    only_ep = DisaggConfig({"EP": RoleSpec(2, hw=H800)})
    with pytest.raises(RuntimeError):
        Cluster(CFG, H800, only_ep, SLO).route(reqs[0], Stage.DECODE)


def test_hetero_simulates_end_to_end():
    stats, done, cl = simulate_once(CFG, H800, HETERO, PROFILE, SLO,
                                    rate=8.0, n_requests=40,
                                    image_tokens=IMG, seed=0)
    assert len(done) == 40
    assert stats.attainment > 0.9
    # decode iterations really ran on the bandwidth-light pool
    l40s = [i for i in cl.instances if i.hw.name == "L40S"]
    assert sum(i.iters for i in l40s) > 0


# ---------------------------------------------------------------------------
# autotuner: warm bisection, caching, bound soundness, argmax preservation
# ---------------------------------------------------------------------------
def test_bisect_goodput_converges_and_warm_start_helps():
    def attain(rate):
        return 1.0 if rate <= 10.0 else 0.0

    g = _bisect_goodput(attain, hi_cap=64.0, guess=None, target=0.9,
                        tol=0.125)
    assert 9.875 <= g <= 10.125
    calls = []

    def counting(rate):
        calls.append(rate)
        return attain(rate)

    g2 = _bisect_goodput(counting, hi_cap=64.0, guess=10.0, target=0.9,
                         tol=0.125)
    assert 9.875 <= g2 <= 10.125
    assert calls[0] == 10.0          # warm start probes the incumbent first
    # a candidate dead even at the floor rate costs exactly two probes
    calls.clear()
    assert _bisect_goodput(counting, hi_cap=64.0, guess=50.0, target=0.9,
                           tol=0.125, lo_floor=20.0) == 0.0
    assert len(calls) == 2


def test_sim_cache_dedupes():
    calls = []

    def sim(disagg, rate):
        calls.append((disagg.name, rate))
        return 1.0

    cache = _SimCache(sim)
    dc = DisaggConfig({"EPD": 2})
    assert cache.attain(dc, 4.0) == 1.0
    assert cache.attain(dc, 4.0) == 1.0
    assert cache.n_sims == 1 and len(calls) == 1


def test_autotuner_matches_exhaustive_argmax():
    """Pruning must never discard the true argmax: on a small grid the
    autotuner's winner attains the same goodput as exhaustive search, and
    every cost-model bound dominates the candidate's simulated goodput."""
    cands = enumerate_disaggs(3)
    kw = dict(candidates=cands, image_tokens=IMG, n_requests=200,
              max_rate=384.0)
    ex = search_disaggregation(CFG, H800, PROFILE, SLO, **kw)
    au = autotune_disaggregation(CFG, H800, PROFILE, SLO, **kw)
    ex_best = max(g for _, g in ex.details)
    assert au.goodput >= ex_best - 0.13
    assert au.disagg.name == ex.disagg.name
    assert au.n_sims < ex.n_sims
    # bound soundness: no candidate simulates above its upper bound
    stats = workload_stats(PROFILE, IMG)
    for dc, g in ex.details:
        b = upper_bound_goodput(CFG, H800, dc, stats, SLO, n_requests=200)
        assert g <= min(384.0, b) + 0.13, dc.name


def test_autotuner_handles_hetero_candidates():
    cands = enumerate_hetero_disaggs([(H800, 2), (L40S, 2)],
                                     methods=["EP+D", "ED+P"])
    res = autotune_disaggregation(CFG, H800, PROFILE, SLO, candidates=cands,
                                  image_tokens=IMG, n_requests=60,
                                  max_rate=48.0)
    assert res.disagg.name in {c.name for c in cands}
    assert res.disagg.heterogeneous
    assert res.goodput > 0.0
    for c in res.details:
        assert (c.goodput is None) == c.pruned
