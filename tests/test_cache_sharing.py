"""Cache-correctness battery for prefix + image-embedding caching with
copy-on-write block sharing (ISSUE 6, DESIGN.md §14).

Three layers, cheapest first:

  1. model-based allocator invariants — random interleavings of
     submit/match/COW-write/decode-extend/abort over a tiny host
     ``PagedCache``, checking after every op that refcounts equal
     block-table occurrences, the free list is disjoint from live and
     evictable blocks, nothing is freed while shared, and every request
     reads back exactly the content its key stream implies (so any
     cross-request corruption is caught bit-exactly).  Runs 500+ seeded
     interleavings unconditionally; the same driver is also exposed
     through hypothesis (via tests/_hyp.py) when it is installed.
  2. device-backend COW — the jitted block-duplication path of
     ``DevicePagedCache`` leaves the donor's pages bit-exact.
  3. engine-level parity — greedy decode after a prefix/image cache hit
     is token-for-token identical to the cold path across the
     GQA/MLA/cross-attn/window/hybrid-SSM config matrix, divergent
     sharers stay bit-exact through COW, and aborting a sharer never
     perturbs the survivor.
"""
import json
from collections import Counter

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import SamplingParams, Stage
from repro.core.simulator import DisaggConfig
from repro.engine.api import Engine
from repro.engine.paged_cache import (DevicePagedCache, PagedCache,
                                      PagedCacheSpec)
from repro.models import model as M

from _hyp import given, settings, st
from conftest import assert_all_reclaimed, reduced_cfg

BS = 4            # tiny blocks so interleavings hit block boundaries often
NUM_BLOCKS = 24
WIDTH = 3


def _spec(num_blocks=NUM_BLOCKS):
    return PagedCacheSpec(n_tensors=1, n_layers=1, block_size=BS,
                          width=WIDTH, num_blocks=num_blocks,
                          dtype=np.float32)


def _val(key) -> float:
    """Deterministic per-key cell value: content checks become exact."""
    return (hash(key) % 65521) / 65521.0


def _rows(keys):
    """[1, 1, len(keys), WIDTH] cache rows derived from the keys."""
    return np.asarray([[[ [_val(k)] * WIDTH for k in keys ]]], np.float32)


# ---------------------------------------------------------------------------
# 1. model-based random-interleaving driver
# ---------------------------------------------------------------------------
class Driver:
    """Random submit/match/append/extend/free interleavings with full
    invariant + content verification after every operation."""

    def __init__(self, seed: int, num_blocks: int = NUM_BLOCKS):
        self.rng = np.random.default_rng(seed)
        self.cache = PagedCache(_spec(num_blocks), sharing=True)
        self.keys: dict[int, list] = {}       # live rid -> its key stream
        self.pool: list[list] = []            # recent streams (prefix bias)
        self.next_rid = 0

    # -- operations --------------------------------------------------------
    def _new_keys(self):
        rng = self.rng
        keys = []
        if self.pool and rng.random() < 0.6:   # biased toward shared prefixes
            base = self.pool[int(rng.integers(len(self.pool)))]
            keys = list(base[:int(rng.integers(0, len(base) + 1))])
        keys += [int(k) for k in rng.integers(0, 50, int(rng.integers(1, 20)))]
        self.pool.append(keys)
        if len(self.pool) > 8:
            self.pool.pop(0)
        return keys

    def op_submit(self):
        rid = self.next_rid
        self.next_rid += 1
        keys = self._new_keys()
        self.cache.set_keys(rid, keys, 0)
        self.keys[rid] = keys
        limit = int(self.rng.integers(1, len(keys) + 1))
        m = self.cache.probe_prefix(keys, 0, limit)
        if m:
            self.cache.take_prefix(rid, m, keys, 0)

    def op_append(self):
        cands = [r for r in self.keys
                 if self.cache.lengths.get(r, 0) < len(self.keys[r])]
        if not cands:
            return
        r = cands[int(self.rng.integers(len(cands)))]
        start = self.cache.lengths.get(r, 0)
        n = int(self.rng.integers(1, min(6, len(self.keys[r]) - start) + 1))
        try:
            self.cache.append(r, _rows(self.keys[r][start:start + n]))
        except MemoryError:
            self.op_free(r)                     # engine aborts on OOM

    def op_extend(self):
        """Decode-style: a new key lands on the live stream, then its row is
        written (the key stream always stays ahead of the cache)."""
        cands = [r for r in self.keys
                 if self.cache.lengths.get(r, 0) == len(self.keys[r])
                 and len(self.keys[r]) > 0]
        if not cands:
            return
        r = cands[int(self.rng.integers(len(cands)))]
        self.keys[r].append(int(self.rng.integers(0, 50)))
        try:
            self.cache.append(r, _rows(self.keys[r][-1:]))
        except MemoryError:
            self.op_free(r)

    def op_free(self, rid=None):
        if rid is None:
            if not self.keys:
                return
            live = sorted(self.keys)
            rid = live[int(self.rng.integers(len(live)))]
        self.cache.free(rid)
        del self.keys[rid]

    # -- invariants --------------------------------------------------------
    def check(self):
        c = self.cache
        occ = Counter(b for t in c.tables.values() for b in t)
        for b in range(c.spec.num_blocks):
            assert c.refcount[b] == occ.get(b, 0), \
                f"block {b}: refcount {c.refcount[b]} != occurrences {occ.get(b, 0)}"
        free = c.allocator.free
        fs = set(free)
        assert len(fs) == len(free), "duplicate free-list entry"
        assert fs.isdisjoint(occ), "freed block still referenced (freed while shared)"
        assert fs.isdisjoint(c.evictable), "block both free and evictable"
        assert set(c.evictable).isdisjoint(occ), "evictable block still live"
        for b in range(c.spec.num_blocks):
            if not occ.get(b, 0):
                assert (b in fs) != (b in c.evictable), f"block {b} leaked"
        assert set(c.evictable) <= set(c.block_hash)
        for h, b in c.hash_block.items():
            assert c.block_hash.get(b) == h, "index maps out of sync"
        for r, keys in self.keys.items():
            n = c.lengths.get(r, 0)
            if not n:
                continue
            np.testing.assert_array_equal(
                c.gather(r), _rows(keys[:n]),
                err_msg=f"rid {r}: content diverged from its key stream")

    def run(self, n_ops: int):
        ops = [self.op_submit, self.op_append, self.op_append,
               self.op_extend, self.op_free]
        for _ in range(n_ops):
            ops[int(self.rng.integers(len(ops)))]()
            self.check()


def test_invariants_500_interleavings():
    """Acceptance: 500+ generated interleavings, every op checked."""
    for seed in range(500):
        Driver(seed).run(24)


def test_invariants_long_runs_with_pressure():
    """Fewer, longer runs on a smaller pool: forces eviction + OOM-abort."""
    total_evictions = 0
    for seed in range(20):
        d = Driver(1000 + seed, num_blocks=10)
        d.run(200)
        total_evictions += d.cache.n_evictions
    assert total_evictions > 0, "pressure runs never exercised eviction"


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_invariants_hypothesis(seed):
    Driver(seed).run(40)


# ---------------------------------------------------------------------------
# targeted allocator semantics
# ---------------------------------------------------------------------------
def test_shared_block_freed_only_at_refcount_zero():
    c = PagedCache(_spec(), sharing=True)
    keys = list(range(10))
    c.set_keys(1, keys, 0)
    c.append(1, _rows(keys))                   # 3 blocks, 2 full registered
    c.set_keys(2, keys, 0)
    m = c.probe_prefix(keys, 0, 9)
    assert m == 8                              # two full blocks
    c.take_prefix(2, m, keys, 0)
    shared = list(c.tables[2])
    assert shared == c.tables[1][:2]
    assert all(c.refcount[b] == 2 for b in shared)
    c.free(1)
    # rid 2 still holds them: neither free nor evictable
    assert all(c.refcount[b] == 1 for b in shared)
    assert not set(shared) & set(c.allocator.free)
    assert not set(shared) & set(c.evictable)
    c.free(2)
    # refcount zero AND indexed -> parked evictable, not freed
    assert all(c.refcount[b] == 0 for b in shared)
    assert set(shared) <= set(c.evictable)
    assert c.allocator.n_free + len(c.evictable) == c.spec.num_blocks


def test_eviction_reclaims_lru_and_prunes_index():
    c = PagedCache(_spec(num_blocks=6), sharing=True)
    for rid, base in ((1, 100), (2, 200)):     # two retired 2-block streams
        keys = [base + i for i in range(8)]
        c.set_keys(rid, keys, 0)
        c.append(rid, _rows(keys))
        c.free(rid)
    assert len(c.evictable) == 4 and c.allocator.n_free == 2
    keys = [300 + i for i in range(20)]        # needs 5 blocks -> evicts 3
    c.set_keys(3, keys, 0)
    c.append(3, _rows(keys))
    assert c.n_evictions == 3
    assert len(c.hash_block) == len(c.block_hash)
    # rid 1 (older) fully evicted; a later probe of its stream misses
    assert c.probe_prefix([100 + i for i in range(8)], 0, 8) == 0
    np.testing.assert_array_equal(c.gather(3), _rows(keys))


def test_cow_write_leaves_donor_bit_exact_host_and_device():
    for cls in (PagedCache, DevicePagedCache):
        c = cls(_spec(), sharing=True)
        keys1 = list(range(12))                # 3 full registered blocks
        c.set_keys(1, keys1, 0)
        c.append(1, _rows(keys1))
        donor = np.asarray(c.gather(1))
        keys2 = keys1[:9] + [99, 98]           # diverges inside block 2
        c.set_keys(2, keys2, 0)
        m = c.probe_prefix(keys2, 0, len(keys2))
        assert m == 8
        c.take_prefix(2, m, keys2, 0)
        c.append(2, _rows(keys2[8:]))          # lands in a fresh block: no COW
        # now force a COW: rid 3 adopts mid-block (hit-cap shape: the donor's
        # tail block is full + registered, the cap stops inside it) and then
        # overwrites inside the still-shared tail block
        keys3 = list(keys1)
        c.set_keys(3, keys3, 0)
        c.take_prefix(3, 9, keys3, 0)          # 3 blocks, tail adopted partial
        shared_tail = c.tables[3][2]
        assert shared_tail == c.tables[1][2] and c.refcount[shared_tail] == 2
        keys3[9] = 77                          # diverge at position 9
        c.append(3, _rows(keys3[9:]))
        assert c.tables[3][2] != c.tables[1][2], "COW did not duplicate"
        assert c.n_cow >= 1
        np.testing.assert_array_equal(np.asarray(c.gather(1)), donor,
                                      err_msg=f"{cls.__name__}: donor corrupted")
        np.testing.assert_array_equal(np.asarray(c.gather(3)), _rows(keys3))


# ---------------------------------------------------------------------------
# engine-level parity battery (GQA / MLA / cross-attn / window / hybrid-SSM)
# ---------------------------------------------------------------------------
ARCHS = ["llava-1.5-7b", "deepseek-v2-236b", "whisper-small", "gemma3-4b",
         "zamba2-7b"]

_params_cache: dict = {}


def _setup(arch):
    cfg = reduced_cfg(arch)
    if arch not in _params_cache:
        _params_cache[arch] = M.init_params(cfg, jax.random.PRNGKey(7))
    return cfg, _params_cache[arch]


def _body(cfg, rng, prompt_len=37):
    prompt = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
    media = None
    if cfg.frontend != "none":
        media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                 * 0.1).astype(np.float32)
    return prompt, media


@pytest.mark.parametrize("arch", ARCHS)
def test_prefix_hit_parity(arch):
    """Greedy continuation after a cache hit is token-for-token identical
    to the cold path; reruns hit (except the SSM-gated hybrid)."""
    cfg, params = _setup(arch)
    prompt, media = _body(cfg, np.random.default_rng(11))
    sp = SamplingParams(max_tokens=4)

    cold = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    ref = cold.generate(prompt, media=media, sampling=sp).tokens()

    warm = Engine(cfg, params, DisaggConfig({"EPD": 1}), prefix_cache=True)
    first = warm.generate(prompt, media=media, sampling=sp).tokens()
    hit = warm.generate(prompt, media=media, sampling=sp).tokens()
    assert first == ref, f"{arch}: cache-on cold run diverged"
    assert hit == ref, f"{arch}: post-hit continuation diverged"

    stats = warm.cache_stats()
    if arch == "zamba2-7b":
        # recurrent layers: prefix sharing is gated off for safety
        assert stats["cached_prompt_tokens"] == 0
    else:
        assert stats["cached_prompt_tokens"] > 0, f"{arch}: no prefix hit"
    if media is not None:
        assert stats["encode_hit_rate"] > 0, f"{arch}: no encode hit"
    assert_all_reclaimed(warm.server)


def test_cow_divergence_engine_bit_exact(rng):
    """Two concurrent sharers adopt the same resident prefix capped
    mid-block; their suffix writes copy-on-write the shared tail block and
    both decode exactly as their cold references."""
    cfg, params = _setup("llava-1.5-7b")
    # media(16) + prompt(32) = 48 = exactly 3 blocks: the probe cap at
    # prefill_total-1 = 47 forces a mid-block adoption of the tail block
    prompt, media = _body(cfg, np.random.default_rng(21), prompt_len=32)
    sp = SamplingParams(max_tokens=5)

    cold = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    ref = cold.generate(prompt, media=media, sampling=sp).tokens()

    warm = Engine(cfg, params, DisaggConfig({"EPD": 1}), prefix_cache=True)
    warm.generate(prompt, media=media, sampling=sp).tokens()  # populate
    b = warm.generate(prompt, media=media, sampling=sp)
    c = warm.generate(prompt, media=media, sampling=sp)
    warm.drain()
    assert list(warm.result(b.rid).generated) == ref
    assert list(warm.result(c.rid).generated) == ref
    req = warm.result(b.rid).req
    assert req.prefix_cached_tokens == 47      # mid-block hit
    assert warm.cache_stats()["cow_copies"] >= 1, "shared tail never COWed"
    assert_all_reclaimed(warm.server)


def test_abort_sharer_mid_prefill_survivor_unchanged(rng):
    """Abort one of two requests sharing a long resident prefix while its
    miss-suffix prefill is in flight: the survivor's output is bit-exact
    and every block is reclaimed only when its refcount reaches zero."""
    cfg, params = _setup("llava-1.5-7b")
    base = rng.integers(0, cfg.vocab_size, 200).astype(np.int32)
    ext = rng.integers(0, cfg.vocab_size, 120).astype(np.int32)
    media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
             * 0.1).astype(np.float32)
    long_prompt = np.concatenate([base, ext])
    sp = SamplingParams(max_tokens=4)

    cold = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    ref_survivor = cold.generate(base, media=media, sampling=sp).tokens()

    warm = Engine(cfg, params, DisaggConfig({"EPD": 1}), prefix_cache=True,
                  kv_blocks=256)
    warm.generate(base, media=media, sampling=sp).tokens()     # populate
    # victim: same 216-token resident prefix + 120 fresh tokens -> its
    # miss suffix spans multiple chunks, so it aborts mid-prefill while
    # sharing; survivor: pure replay of the resident prefix
    victim = warm.generate(long_prompt, media=media, sampling=sp)
    survivor = warm.generate(base, media=media, sampling=sp)
    vreq = warm.result(victim.rid).req
    for _ in range(200):                        # step into victim's prefill
        if vreq.stage == Stage.PREFILL and \
                vreq.prefill_done > vreq.prefix_cached_tokens > 0:
            break
        warm.step()
    assert vreq.prefix_cached_tokens > 0, "victim never shared the prefix"
    kv = warm.server.instances[0].caches.kv
    shared_now = [b for b in kv.tables[victim.rid]
                  if kv.refcount[b] > 1]
    assert shared_now, "victim not sharing any block at abort time"
    assert warm.abort(victim.rid)
    # survivor's references keep every shared block alive
    assert all(kv.refcount[b] >= 1 for b in shared_now)
    warm.drain()
    assert list(warm.result(survivor.rid).generated) == ref_survivor
    assert_all_reclaimed(warm.server)


def test_multiturn_conversation_hits_grow(rng):
    """Each turn resends the history: the prefix cache should convert all
    but the fresh suffix into hits, turn over turn."""
    cfg, params = _setup("llava-1.5-7b")
    sp = SamplingParams(max_tokens=4)
    warm = Engine(cfg, params, DisaggConfig({"EPD": 1}), prefix_cache=True)
    cold = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    history = list(rng.integers(0, cfg.vocab_size, 24))
    cached = []
    for turn in range(3):
        prompt = np.asarray(history, np.int32)
        st_w = warm.generate(prompt, sampling=sp)
        st_c = cold.generate(prompt, sampling=sp)
        toks_w, toks_c = st_w.tokens(), st_c.tokens()
        assert toks_w == toks_c, f"turn {turn} diverged"
        cached.append(warm.result(st_w.rid).req.prefix_cached_tokens)
        history += toks_w + list(rng.integers(0, cfg.vocab_size, 12))
    assert cached[0] == 0
    assert cached[2] > cached[1] > 0, f"hits did not grow: {cached}"
    assert_all_reclaimed(warm.server)


# ---------------------------------------------------------------------------
# benchmark registration + smoke (CI runs this via pytest)
# ---------------------------------------------------------------------------
def test_bench_cache_registered_and_smokes(monkeypatch, tmp_path):
    import benchmarks.run as bench_run
    assert "benchmarks.bench_cache" in bench_run.MODULES
    assert "benchmarks.bench_cache" in bench_run.QUICK

    import benchmarks.bench_cache as bench
    monkeypatch.setattr(bench, "N_CONVS", 2)
    monkeypatch.setattr(bench, "TURNS", 2)
    monkeypatch.setattr(bench, "SYSTEM_TOKENS", 24)
    monkeypatch.setattr(bench, "N_IMG_REQS", 3)
    monkeypatch.setattr(bench, "MAX_NEW", 3)
    bench._params_cache.clear()
    rows = bench.run(out=tmp_path / "BENCH_cache.json")
    names = [r[0] for r in rows]
    assert "cache/p90_ttft_on" in names and "cache/p90_ttft_off" in names
    rec = json.loads((tmp_path / "BENCH_cache.json").read_text())
    assert 0.0 <= rec["prefix_hit_rate"] <= 1.0
    assert 0.0 <= rec["encode_hit_rate"] <= 1.0
    assert rec["prefix_hit_rate"] > 0, "smoke trace produced no prefix hits"
