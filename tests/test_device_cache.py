"""Device-resident paged decode: parity vs the dense-gather path, cache
migration round-trips, stall guard, routing, and the engine benchmark."""
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.configs import get_config
from repro.core.costmodel import H800, L40S
from repro.core.request import Stage
from repro.core.simulator import DisaggConfig, RoleSpec
from repro.engine.paged_cache import (DevicePagedCache, PagedCache,
                                      PagedCacheSpec, StateStore,
                                      migrate_request)
from repro.engine.runner import ModelRunner, RunnerCaches
from repro.engine.server import HydraServer
from repro.models import model as M

from conftest import reduced_cfg


def _prefill(runner, cfg, rid, prompt, media):
    if media is not None:
        runner.encode([(rid, media)])
        if not cfg.cross_attention:
            runner.prefill_chunk(rid, None, use_media=True)
    return runner.prefill_chunk(rid, prompt)


def _setup_pair(arch, rng, *, attn_impl="interpret", n_req=3):
    """Two runners over the same params: dense-gather vs device-paged."""
    cfg = reduced_cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    dense = ModelRunner(cfg, params, RunnerCaches(cfg, kv_blocks=32,
                                                  img_blocks=4))
    paged = ModelRunner(cfg, params,
                        RunnerCaches(cfg, kv_blocks=32, img_blocks=4,
                                     device=True),
                        attn_impl=attn_impl)
    rids, last = [], []
    for rid in range(n_req):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=6 + 3 * rid).astype(np.int32)
        media = None
        if cfg.frontend != "none":
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        l_d = _prefill(dense, cfg, rid, prompt, media)
        l_p = _prefill(paged, cfg, rid, prompt, media)
        np.testing.assert_allclose(l_p, l_d, atol=1e-4)
        rids.append(rid)
        last.append(int(np.argmax(l_d)))
    return cfg, dense, paged, rids, np.asarray(last)


# ---------------------------------------------------------------------------
# parity: device-paged decode logits == dense-gather decode logits, per step,
# heterogeneous context lengths, across attention families
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [
    "llava-1.5-7b",        # dense GQA attention + vision media
    "deepseek-v2-236b",    # MLA (latent paged cache) + MoE
    "whisper-small",       # cross-attention (state-store KV) + audio
    "gemma3-4b",           # sliding-window local layers
    "zamba2-7b",           # hybrid: shared attention + mamba state
])
def test_paged_decode_matches_dense(rng, arch):
    cfg, dense, paged, rids, toks = _setup_pair(arch, rng)
    for _ in range(4):
        l_d = dense.decode(rids, toks)
        l_p = paged.decode(rids, toks)
        scale = np.abs(l_d).max() + 1e-9
        assert np.abs(l_p - l_d).max() / scale < 2e-4
        toks = np.argmax(l_d, axis=-1)


def test_paged_decode_matches_dense_ref_impl(rng):
    """Same parity through the pure-jnp oracle backend (the fast CPU path)."""
    cfg, dense, paged, rids, toks = _setup_pair("llava-1.5-7b", rng,
                                                attn_impl="ref")
    for _ in range(3):
        l_d = dense.decode(rids, toks)
        l_p = paged.decode(rids, toks)
        scale = np.abs(l_d).max() + 1e-9
        assert np.abs(l_p - l_d).max() / scale < 2e-4
        toks = np.argmax(l_d, axis=-1)


def test_paged_decode_no_host_cache_traffic(rng):
    """The acceptance property: a paged decode step must not gather the
    cache to the host (``gather``) nor re-append via the host path."""
    cfg, dense, paged, rids, toks = _setup_pair("llava-1.5-7b", rng)

    def banned(*a, **k):  # pragma: no cover - only hit on regression
        raise AssertionError("decode touched the host gather/append path")

    kv = paged.caches.kv
    kv.gather = banned
    kv.append = banned
    paged.decode(rids, toks)


# ---------------------------------------------------------------------------
# DevicePagedCache: host-interop surface + migration round-trip
# ---------------------------------------------------------------------------
def test_device_cache_append_gather_matches_numpy(rng):
    spec = PagedCacheSpec(n_tensors=2, n_layers=3, block_size=4, width=8,
                          num_blocks=16)
    host, dev = PagedCache(spec), DevicePagedCache(spec)
    data = rng.standard_normal((2, 3, 10, 8)).astype(np.float32)
    for c in (host, dev):
        c.append(7, data[:, :, :6])
        c.append(7, data[:, :, 6:])
    np.testing.assert_array_equal(np.asarray(dev.gather(7)), host.gather(7))
    assert dev.nbytes(7) == host.nbytes(7)


@pytest.mark.parametrize("direction", ["dev->host", "host->dev", "dev->dev"])
def test_device_cache_migrate_roundtrip(rng, direction):
    spec = PagedCacheSpec(2, 2, 4, 8, 16)
    mk = {"dev": lambda: DevicePagedCache(spec), "host": lambda: PagedCache(spec)}
    s_kind, d_kind = direction.split("->")
    src, dst = mk[s_kind](), mk[d_kind]()
    src_st, dst_st = StateStore(), StateStore()
    kv = rng.standard_normal((2, 2, 9, 8)).astype(np.float32)
    src.append(3, kv)
    src_st.put(3, {"state": np.ones((1, 4, 2), np.float32)})
    moved = migrate_request(3, [src, src_st], [dst, dst_st])
    assert moved > 0
    np.testing.assert_allclose(np.asarray(dst.gather(3)), kv)
    assert 3 not in src.tables and src_st.get(3) is None
    assert src.allocator.n_free == spec.num_blocks


def test_device_cache_scratch_block_reserved():
    spec = PagedCacheSpec(1, 1, 4, 8, 8)
    dev = DevicePagedCache(spec)
    blocks = dev.allocator.alloc(8)
    assert dev.scratch_block not in blocks  # pad lanes own it exclusively
    tables, slots = DevicePagedCache(spec).prepare_decode([], 2, 2)
    assert (tables == spec.num_blocks).all()
    assert (slots == spec.num_blocks * spec.block_size).all()


# ---------------------------------------------------------------------------
# server satellites
# ---------------------------------------------------------------------------
def test_stall_guard_diagnoses_capacity_deadlock(rng):
    cfg = reduced_cfg("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}), kv_blocks=1)
    prompt = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    srv.submit(prompt, max_new_tokens=4)   # can never fit in one block
    with pytest.raises(RuntimeError, match="capacity deadlock"):
        srv.run(stall_iters=5)


def test_admission_reserves_capacity_no_mid_run_oom(rng):
    """Two requests that each fit alone but not together must serialize
    (second admitted after the first frees), not OOM the allocator."""
    cfg = reduced_cfg("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # pool: 8 blocks = 128 tokens; each request needs ~89, two need ~144
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}), kv_blocks=8)
    rids = [srv.submit(rng.integers(0, cfg.vocab_size, 48).astype(np.int32),
                       max_new_tokens=24) for _ in range(2)]
    out = srv.run()
    for rid in rids:
        assert len(out[rid].generated) == 24


def test_encode_admission_reserves_image_blocks(rng):
    """Same double-admission hazard on the image cache: two encode requests
    with one free image block must serialize, not OOM mid-encode."""
    cfg = reduced_cfg("llava-1.5-7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}), img_blocks=1)
    rids = []
    for _ in range(2):
        prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                 * 0.1).astype(np.float32)
        rids.append(srv.submit(prompt, media=media, max_new_tokens=3))
    out = srv.run()
    for rid in rids:
        assert len(out[rid].generated) == 3


def test_encode_admission_reserves_kv_for_prefill(rng):
    """A media request admitted at ENCODE flips to PREFILL with no further
    capacity check, so its future KV demand must be reserved at encode
    admission: media + text requests that fit alone but not together must
    serialize instead of OOMing the allocator mid-prefill."""
    cfg = reduced_cfg("llava-1.5-7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # 8 blocks = 128 KV tokens; media req needs 16+40+16=72, text req 56
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}), kv_blocks=8)
    media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
             * 0.1).astype(np.float32)
    r0 = srv.submit(rng.integers(0, cfg.vocab_size, 40).astype(np.int32),
                    media=media, max_new_tokens=16)
    r1 = srv.submit(rng.integers(0, cfg.vocab_size, 40).astype(np.int32),
                    max_new_tokens=16)
    out = srv.run()
    assert len(out[r0].generated) == 16 and len(out[r1].generated) == 16


def test_stall_guard_spares_future_arrivals(rng):
    """Pending requests with a future ready_at are a legitimate wait, not a
    deadlock: the guard must keep spinning instead of raising."""
    cfg = reduced_cfg("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}))
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    srv.submit(prompt, max_new_tokens=2, arrival=0.2)  # ready in the future
    out = srv.run(stall_iters=5)
    assert len(out[0].generated) == 2


def test_speed_normalized_routing():
    cfg = reduced_cfg("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = HydraServer(cfg, params, DisaggConfig(
        {"PD": RoleSpec(1, hw=L40S), "D": RoleSpec(1, hw=H800)}))
    # equal (empty) queues: decode routes to the bandwidth-heavy instance
    assert srv._route(Stage.DECODE).role_name == "D"
    # prefill can only go to the PD instance
    assert srv._route(Stage.PREFILL).role_name == "PD"
    # pile work onto the fast decode instance until the slow one wins
    d = next(i for i in srv.instances if i.role_name == "D")
    pd = next(i for i in srv.instances if i.role_name == "PD")
    ratio = srv._speed(d, Stage.DECODE) / srv._speed(pd, Stage.DECODE)
    d.running = list(range(int(ratio) + 1))
    assert srv._route(Stage.DECODE).role_name == "PD"


def test_real_instance_queue_holds_bare_requests(rng):
    cfg = reduced_cfg("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}))
    srv.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
               max_new_tokens=1)
    inst = srv.instances[0]
    (r,) = inst.waiting                       # no (request, pull) tuples
    assert r.rid == 0 and not hasattr(inst, "_pending_pull")


# ---------------------------------------------------------------------------
# benchmark registration + smoke (CI runs this via pytest)
# ---------------------------------------------------------------------------
def test_bench_engine_registered_and_smokes(monkeypatch, tmp_path):
    import benchmarks.run as bench_run
    assert "benchmarks.bench_engine_throughput" in bench_run.MODULES
    assert "benchmarks.bench_engine_throughput" in bench_run.QUICK

    import benchmarks.bench_engine_throughput as bench
    monkeypatch.setattr(bench, "B", 2)
    monkeypatch.setattr(bench, "MAX_NEW", 3)
    bench._drive._params.clear()
    rows = bench.run(out=tmp_path / "BENCH_engine.json")
    names = [r[0] for r in rows]
    assert "engine/decode/dense" in names
    assert "engine/decode/paged-interpret" in names
    assert (tmp_path / "BENCH_engine.json").exists()
