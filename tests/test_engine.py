"""Engine integration tests: paged caches, migration, end-to-end serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.simulator import DisaggConfig
from repro.engine.paged_cache import (BlockAllocator, PagedCache,
                                      PagedCacheSpec, StateStore,
                                      migrate_request)
from repro.engine.server import HydraServer
from repro.models import model as M

from conftest import reduced_cfg


# ---------------------------------------------------------------------------
# paged cache unit tests
# ---------------------------------------------------------------------------
def test_allocator_exhaustion_and_release():
    a = BlockAllocator(4)
    blocks = a.alloc(4)
    assert a.n_free == 0
    with pytest.raises(MemoryError):
        a.alloc(1)
    a.release(blocks[:2])
    assert a.n_free == 2


def test_paged_cache_append_gather_roundtrip(rng):
    spec = PagedCacheSpec(n_tensors=2, n_layers=3, block_size=4, width=8,
                          num_blocks=16)
    c = PagedCache(spec)
    data = rng.standard_normal((2, 3, 10, 8)).astype(np.float32)
    c.append(7, data[:, :, :6])
    c.append(7, data[:, :, 6:])
    out = c.gather(7)
    np.testing.assert_array_equal(out, data)
    c.free(7)
    assert c.allocator.n_free == 16


def test_paged_cache_interleaved_requests(rng):
    spec = PagedCacheSpec(1, 1, 4, 8, 32)
    c = PagedCache(spec)
    ref = {}
    for rid in range(5):
        ref[rid] = rng.standard_normal((1, 1, 3 + rid, 8)).astype(np.float32)
        c.append(rid, ref[rid])
    for rid in range(5):
        extra = rng.standard_normal((1, 1, 2, 8)).astype(np.float32)
        c.append(rid, extra)
        ref[rid] = np.concatenate([ref[rid], extra], axis=2)
    for rid in range(5):
        np.testing.assert_array_equal(c.gather(rid), ref[rid])


def test_migrate_request_moves_everything(rng):
    spec = PagedCacheSpec(2, 2, 4, 8, 16)
    src_kv, dst_kv = PagedCache(spec), PagedCache(spec)
    src_st, dst_st = StateStore(), StateStore()
    kv = rng.standard_normal((2, 2, 9, 8)).astype(np.float32)
    src_kv.append(3, kv)
    src_st.put(3, {"state": np.ones((1, 4, 2), np.float32)})
    moved = migrate_request(3, [src_kv, src_st], [dst_kv, dst_st])
    assert moved > 0
    np.testing.assert_array_equal(dst_kv.gather(3), kv)
    np.testing.assert_array_equal(dst_st.get(3)["state"],
                                  np.ones((1, 4, 2), np.float32))
    # 4-step protocol step 4: source released its resources
    assert 3 not in src_kv.tables and src_st.get(3) is None


# ---------------------------------------------------------------------------
# end-to-end: disaggregated serving must equal direct generation
# ---------------------------------------------------------------------------
def _ref_generate(cfg, params, prompt, media, n_new):
    kw = {}
    n_media = 0
    if media is not None and cfg.frontend == "audio":
        kw["frames"] = jnp.asarray(media)[None]
    elif media is not None:
        kw["media"] = jnp.asarray(media)[None]
        n_media = media.shape[0]
    last, pc = M.prefill(cfg, params, jnp.asarray(prompt)[None], **kw)
    S_tot = len(prompt) + n_media
    cache = M.build_cache_from_prefill(cfg, pc, max_len=S_tot + n_new + 1)
    toks = [int(jnp.argmax(last[0]))]
    cl = S_tot
    for _ in range(n_new - 1):
        lg, cache = M.decode_step(cfg, params, cache, jnp.int32(cl),
                                  jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        cl += 1
    return toks


@pytest.mark.parametrize("arch,disagg", [
    ("llava-1.5-7b", {"E": 1, "P": 1, "D": 1}),
    ("llava-1.5-7b", {"EP": 1, "D": 1}),
    ("falcon-mamba-7b", {"P": 1, "D": 1}),
    ("zamba2-7b", {"PD": 1}),
    ("whisper-small", {"E": 1, "PD": 1}),
    ("granite-moe-1b-a400m", {"EPD": 1}),
])
def test_server_matches_direct_generation(rng, arch, disagg):
    cfg = reduced_cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    reqs = []
    for i in range(3):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(6, 14))).astype(np.int32)
        media = None
        if cfg.frontend != "none":
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        reqs.append((prompt, media, 5))
    refs = [_ref_generate(cfg, params, *r) for r in reqs]
    srv = HydraServer(cfg, params, DisaggConfig(disagg))
    rids = [srv.submit(p, media=m, max_new_tokens=n) for p, m, n in reqs]
    out = srv.run()
    for rid, ref in zip(rids, refs):
        assert out[rid].generated == ref
    if len(disagg) > 1:
        assert srv.n_migrations > 0


def test_chunked_prefill_matches_forward(rng):
    """Three uneven chunks + media-first == one full forward."""
    cfg = reduced_cfg("pixtral-12b")
    params = M.init_params(cfg, jax.random.PRNGKey(6))
    B, S = 1, 30
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    media = jnp.asarray(rng.standard_normal((B, cfg.media_tokens,
                                             cfg.d_model)) * 0.1, jnp.float32)
    ref, _, _ = M.forward(cfg, params, tokens, media=media)
    media_emb = M.encode_media(cfg, params, media)
    prior = M.empty_prior(cfg, B)
    lg, ents = M.prefill_chunk(cfg, params, None, prior, 0,
                               media_emb=media_emb)
    prior = M.extend_prior(cfg, prior, ents)
    off = cfg.media_tokens
    for sl in (slice(0, 11), slice(11, 17), slice(17, S)):
        lg, ents = M.prefill_chunk(cfg, params, tokens[:, sl], prior, off)
        prior = M.extend_prior(cfg, prior, ents)
        off += sl.stop - sl.start
    scale = float(jnp.max(jnp.abs(ref[:, -1]))) + 1e-9
    assert float(jnp.max(jnp.abs(lg - ref[:, -1]))) / scale < 1e-3
