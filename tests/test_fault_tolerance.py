"""Fault-tolerance tests (DESIGN.md §15): deterministic fault injection,
instance failure + journal replay with bit-exact continuation, transactional
checksummed transfers with retry, health state machine, deadline-aware load
shedding, graceful engine shutdown, and the hardened HTTP front."""
import http.client
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.request import SLO, SamplingParams, Stage
from repro.core.simulator import DisaggConfig
from repro.engine.api import Engine
from repro.engine.faults import (AdmissionError, FaultEvent, FaultPlan,
                                 TransferError, corrupt_payload,
                                 payload_checksum)
from repro.engine.server import HydraServer
from repro.models import model as M

from _hyp import given, settings, st
from conftest import assert_all_reclaimed, reduced_cfg


@pytest.fixture(scope="module")
def llava():
    cfg = reduced_cfg("llava-1.5-7b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(5))


def _workload(cfg, seed=0, n=3, prompt_len=12):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
        media = None
        if i % 2 == 0:
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        reqs.append((prompt, media))
    return reqs


def _drive(server, max_iters=2000):
    """Step until every submitted request is done (fault-aware: no stall
    guard — shedding/replay may legitimately take a while)."""
    for _ in range(max_iters):
        if all(it.req.done for it in server.items.values()):
            return
        if not server.step():
            time.sleep(0.001)
    raise AssertionError("requests did not finish")


def _drive_until(server, pred, max_iters=2000):
    for _ in range(max_iters):
        if pred():
            return True
        if not server.step():
            time.sleep(0.001)
    return False


def _holder(server, r):
    for inst in server.instances:
        if r in inst.running or r in inst.waiting:
            return inst
    return None


def _baseline(cfg, params, reqs, max_new=6, **kw):
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}), **kw)
    rids = [srv.submit(p, media=m, max_new_tokens=max_new) for p, m in reqs]
    out = srv.run()
    return [list(out[r].generated) for r in rids]


# ---------------------------------------------------------------------------
# fault-plan unit tests (no model)
# ---------------------------------------------------------------------------
def test_fault_plan_parse_and_windows():
    plan = FaultPlan.parse("crash@10:1,stall@5:0+3,alloc@7,drop@4+2")
    kinds = sorted(e.kind for e in plan.events)
    assert kinds == ["alloc", "crash", "drop", "stall"]
    # crash fires once, at-or-after its iteration, only for its iid
    assert not plan.crash(9, 1)
    assert not plan.crash(10, 0)
    assert plan.crash(11, 1)
    assert not plan.crash(12, 1)          # one-shot
    # stall window [5, 8) on iid 0 only
    assert plan.stalled(5, 0) and plan.stalled(7, 0)
    assert not plan.stalled(8, 0) and not plan.stalled(6, 1)
    # alloc window length defaults to 1; iid -1 matches anyone
    assert plan.alloc_fail(7, 3) and not plan.alloc_fail(8, 3)
    # transfer events gate on the attempt index: arg=2 fails attempts 0-1
    assert plan.transfer_fault(4, 0) == "drop"
    assert plan.transfer_fault(4, 1) == "drop"
    assert plan.transfer_fault(4, 2) is None
    assert plan.transfer_fault(5, 0) is None

    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.parse("crash@")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("melt@3")


def test_fault_plan_random_keeps_a_survivor():
    for seed in range(8):
        plan = FaultPlan.random(seed, horizon=50, iids=[0, 1],
                                p_crash=1.0, max_crashes=5)
        assert sum(1 for e in plan.events if e.kind == "crash") <= 1
    # deterministic from the seed
    a = FaultPlan.random(3, horizon=20, iids=[0, 1], p_crash=1.0,
                         max_crashes=1)
    b = FaultPlan.random(3, horizon=20, iids=[0, 1], p_crash=1.0,
                         max_crashes=1)
    assert a.events == b.events


def test_payload_checksum_catches_corruption():
    rng = np.random.default_rng(0)
    payload = {"k": rng.standard_normal((4, 8)).astype(np.float32),
               "meta": {"len": 7}}
    ck = payload_checksum(payload)
    assert ck == payload_checksum({"meta": {"len": 7}, "k": payload["k"]})
    bad = corrupt_payload(payload)
    assert payload_checksum(bad) != ck
    # corruption returns a copy: the original stays intact (retries must
    # see clean data)
    assert payload_checksum(payload) == ck


# ---------------------------------------------------------------------------
# crash at every stage: zero lost requests + bit-exact greedy continuation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stage", ["queued", "post_encode", "mid_prefill",
                                   "decode"])
def test_crash_recovery_bit_exact(llava, stage):
    from repro.core.budgets import Budgets

    cfg, params = llava
    reqs = _workload(cfg, seed=11, n=3, prompt_len=40)
    kw = dict(budgets=Budgets(16, 4))   # small chunks: prefill spans steps
    expected = _baseline(cfg, params, reqs, **kw)

    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}), **kw)
    rids = [srv.submit(p, media=m, max_new_tokens=6) for p, m in reqs]
    r0 = srv.items[rids[0]].req       # the victim (has an image)

    preds = {
        "queued": lambda: True,
        "post_encode": lambda: r0.stage == Stage.PREFILL,
        "mid_prefill": lambda: 0 < r0.prefill_done < r0.prefill_total,
        "decode": lambda: r0.tokens_out >= 2,
    }
    assert _drive_until(srv, preds[stage]), f"never reached {stage}"
    holder = _holder(srv, r0)
    if holder is None:                 # finished too fast to catch: rerun
        pytest.skip(f"stage {stage} window too narrow on this host")
    assert srv.kill_instance(holder.iid)
    _drive(srv)

    got = [list(srv.items[r].generated) for r in rids]
    assert got == expected             # bit-exact greedy continuation
    for r in rids:                     # zero lost requests
        assert srv.items[r].req.finish_reason in ("length", "stop")
    assert srv.fault_stats()["dead_instances"] == [holder.iid]
    assert_all_reclaimed(srv)


def test_crash_recovery_with_prefix_cache(llava):
    cfg, params = llava
    reqs = _workload(cfg, seed=7, n=2, prompt_len=16)
    expected = _baseline(cfg, params, reqs, prefix_cache=True)

    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}),
                      prefix_cache=True)
    rids = [srv.submit(p, media=m, max_new_tokens=6) for p, m in reqs]
    r0 = srv.items[rids[0]].req
    assert _drive_until(srv, lambda: r0.tokens_out >= 2)
    holder = _holder(srv, r0)
    if holder is None:
        pytest.skip("decode window too narrow on this host")
    srv.kill_instance(holder.iid)
    _drive(srv)
    assert [list(srv.items[r].generated) for r in rids] == expected
    assert all(srv.items[r].req.finish_reason in ("length", "stop")
               for r in rids)
    assert_all_reclaimed(srv)


def test_plan_driven_crash_via_run(llava):
    """A FaultPlan crash mid-run through the legacy closed-loop driver."""
    cfg, params = llava
    reqs = _workload(cfg, seed=3, n=3)
    expected = _baseline(cfg, params, reqs)
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}),
                      fault_plan=FaultPlan([FaultEvent(3, "crash", iid=1)]))
    rids = [srv.submit(p, media=m, max_new_tokens=6) for p, m in reqs]
    out = srv.run()
    assert [list(out[r].generated) for r in rids] == expected
    assert srv.fault_stats()["dead_instances"] == [1]
    assert_all_reclaimed(srv)


# ---------------------------------------------------------------------------
# transfer faults: checksummed retry, then exhaustion -> replay/shed
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["drop", "corrupt"])
def test_transfer_retry_succeeds(llava, kind):
    cfg, params = llava
    reqs = _workload(cfg, seed=5, n=2)
    disagg = DisaggConfig({"E": 1, "P": 1, "D": 1})
    srv0 = HydraServer(cfg, params, disagg)
    rids0 = [srv0.submit(p, media=m, max_new_tokens=5) for p, m in reqs]
    expected = [list(srv0.run()[r].generated) for r in rids0]

    # every migration's FIRST attempt fails (arg=1); the retry must succeed
    plan = FaultPlan([FaultEvent(i, kind, arg=1) for i in range(200)])
    srv = HydraServer(cfg, params, disagg, fault_plan=plan)
    rids = [srv.submit(p, media=m, max_new_tokens=5) for p, m in reqs]
    out = srv.run()
    assert [list(out[r].generated) for r in rids] == expected
    fs = srv.fault_stats()
    assert fs["transfer_retries"] > 0 and fs["transfer_failures"] == 0
    retried = [e for e in fs["log"] if e["kind"] == "transfer_retry"]
    assert retried and all(e["fault"] == kind for e in retried)
    assert_all_reclaimed(srv)


def test_transfer_exhaustion_sheds(llava):
    """Permanently failing transfers burn the retry budget, then the
    recovery budget, and finally shed with finish_reason="error" — blocks
    conserved throughout."""
    cfg, params = llava
    plan = FaultPlan([FaultEvent(i, "drop", arg=99) for i in range(500)])
    srv = HydraServer(cfg, params, DisaggConfig({"E": 1, "P": 1, "D": 1}),
                      fault_plan=plan, transfer_retries=1,
                      transfer_backoff=0.0, max_recoveries=2)
    prompt = np.arange(8, dtype=np.int32)
    rid = srv.submit(prompt, max_new_tokens=5)
    _drive(srv)
    r = srv.items[rid].req
    assert r.finish_reason == "error"
    fs = srv.fault_stats()
    assert fs["transfer_failures"] >= 1 and fs["shed"] == 1
    assert_all_reclaimed(srv)


def test_migrate_request_rolls_back_on_corruption(llava):
    """Unit-level: a corrupted payload is detected by checksum, the
    destination import is rolled back, and the source copy survives."""
    from repro.core.budgets import Budgets
    from repro.engine import runner as R

    cfg, params = llava
    srv = HydraServer(cfg, params, DisaggConfig({"P": 1, "D": 1}),
                      budgets=Budgets(16, 4))   # chunked: stays on src
    src, dst = srv.instances
    rid = srv.submit(np.arange(24, dtype=np.int32), max_new_tokens=4)
    r = srv.items[rid].req
    assert _drive_until(srv,
                        lambda: 0 < r.prefill_done < r.prefill_total,
                        max_iters=50)
    assert rid in src.caches.kv.tables
    with pytest.raises(TransferError) as ei:
        R.migrate(rid, src.caches, dst.caches, fault="corrupt")
    assert ei.value.kind == "corrupt"
    assert rid in src.caches.kv.tables          # source intact
    assert rid not in dst.caches.kv.tables      # destination rolled back
    srv.abort(rid)
    assert_all_reclaimed(srv)


# ---------------------------------------------------------------------------
# allocation failure mid-batch -> release + replay on the same instance
# ---------------------------------------------------------------------------
def test_alloc_failure_recovers(llava):
    cfg, params = llava
    reqs = _workload(cfg, seed=9, n=2)
    expected = _baseline(cfg, params, reqs)
    plan = FaultPlan([FaultEvent(1, "alloc", arg=2)])
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}),
                      fault_plan=plan)
    rids = [srv.submit(p, media=m, max_new_tokens=6) for p, m in reqs]
    _drive(srv)
    assert [list(srv.items[r].generated) for r in rids] == expected
    fs = srv.fault_stats()
    assert fs["replays"] >= 1
    assert any(e["kind"] == "batch_failed" for e in fs["log"])
    assert_all_reclaimed(srv)


# ---------------------------------------------------------------------------
# health state machine: stall -> degraded -> dead -> requests recovered
# ---------------------------------------------------------------------------
def test_stall_escalates_to_dead_and_recovers(llava):
    cfg, params = llava
    reqs = _workload(cfg, seed=13, n=2)
    expected = _baseline(cfg, params, reqs)
    plan = FaultPlan([FaultEvent(1, "stall", iid=0, arg=10_000)])
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}),
                      fault_plan=plan, degraded_after=2, dead_after=5)
    rids = [srv.submit(p, media=m, max_new_tokens=6) for p, m in reqs]
    _drive(srv)
    assert [list(srv.items[r].generated) for r in rids] == expected
    fs = srv.fault_stats()
    assert fs["dead_instances"] == [0]
    kinds = [e["kind"] for e in fs["log"]]
    assert kinds.index("instance_degraded") < kinds.index("instance_dead")
    assert_all_reclaimed(srv)


def test_stall_diagnosis_names_wedged_instance(llava):
    """With death disabled, a permanently wedged instance trips the stall
    guard with the no-progress diagnostic, NOT the capacity-deadlock one."""
    cfg, params = llava
    plan = FaultPlan([FaultEvent(1, "stall", iid=0, arg=10_000)])
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}),
                      fault_plan=plan, degraded_after=2, dead_after=None)
    srv.submit(np.arange(6, dtype=np.int32), max_new_tokens=3)
    with pytest.raises(RuntimeError, match="no progress") as ei:
        srv.run(stall_iters=10)
    assert "capacity deadlock" not in str(ei.value)
    assert srv.instances[0].health == "degraded"


# ---------------------------------------------------------------------------
# deadline-aware load shedding
# ---------------------------------------------------------------------------
def test_admission_rejects_unserveable(llava):
    cfg, params = llava
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 1}),
                      shed_policy="deadline", kv_blocks=4)
    # KV footprint larger than the whole pool: typed reject at submit
    with pytest.raises(AdmissionError, match="KV tokens"):
        srv.submit(np.arange(400, dtype=np.int32), max_new_tokens=8)
    # unknown shed policy is a config error
    with pytest.raises(ValueError, match="shed_policy"):
        HydraServer(cfg, params, DisaggConfig({"EPD": 1}),
                    shed_policy="bogus")
    # after the only instance dies, every submit is rejected
    srv2 = HydraServer(cfg, params, DisaggConfig({"EPD": 1}),
                       shed_policy="deadline")
    srv2.kill_instance(0)
    with pytest.raises(AdmissionError, match="no live instance"):
        srv2.submit(np.arange(6, dtype=np.int32), max_new_tokens=2)


def test_doomed_requests_shed_under_degraded_capacity(llava):
    cfg, params = llava
    # instance 0 wedged forever (never dies), instance 1 killed: capacity
    # is durably degraded and the queued request's TTFT deadline expires
    plan = FaultPlan([FaultEvent(0, "stall", iid=0, arg=100_000)])
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}),
                      fault_plan=plan, shed_policy="deadline",
                      shed_ttft_factor=1.0, slo=SLO(0.01, 1.0),
                      dead_after=None)
    srv.kill_instance(1)
    rid = srv.submit(np.arange(6, dtype=np.int32), max_new_tokens=3)
    events = []
    srv.on_event = events.append
    deadline = time.monotonic() + 5.0
    r = srv.items[rid].req
    while not r.done and time.monotonic() < deadline:
        srv.step()
        time.sleep(0.002)
    assert r.finish_reason == "error"
    assert [e.kind for e in events] == ["finish"]
    assert events[0].finish_reason == "error"
    assert srv.fault_stats()["shed"] == 1


# ---------------------------------------------------------------------------
# graceful close + abort of retired rids
# ---------------------------------------------------------------------------
def test_engine_close_drains_in_flight(llava):
    cfg, params = llava
    eng = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    s1 = eng.generate(np.arange(8, dtype=np.int32),
                      sampling=SamplingParams(max_tokens=4))
    s2 = eng.generate(np.arange(5, dtype=np.int32),
                      sampling=SamplingParams(max_tokens=4))
    eng.close(drain_timeout=60.0)       # step-driven drain, no thread
    for s in (s1, s2):
        r = eng.result(s.rid).req
        assert r.finish_reason == "length"
        assert len(eng.result(s.rid).generated) == 4
    # abort of a retired rid is a no-op returning False
    assert eng.abort(s1.rid) is False
    eng.release(s1.rid)
    assert eng.abort(s1.rid) is False   # unknown rid: still a no-op
    assert eng.close() is None          # idempotent


def test_engine_close_zero_timeout_aborts(llava):
    cfg, params = llava
    eng = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    s = eng.generate(np.arange(64, dtype=np.int32),
                     sampling=SamplingParams(max_tokens=64))
    eng.close(drain_timeout=0)
    assert eng.result(s.rid).req.finish_reason == "abort"


# ---------------------------------------------------------------------------
# seeded fault-plan sweep: liveness + conservation under random plans
# ---------------------------------------------------------------------------
def _sweep_one(llava, seed):
    cfg, params = llava
    plan = FaultPlan.random(seed, horizon=40, iids=[0, 1], p_crash=1.0,
                            max_crashes=1, p_stall=0.05, p_alloc=0.05,
                            p_transfer=0.1, stall_len=2)
    srv = HydraServer(cfg, params, DisaggConfig({"EPD": 2}),
                      fault_plan=plan, degraded_after=2, dead_after=4,
                      transfer_backoff=0.0)
    reqs = _workload(cfg, seed=seed, n=3)
    rids = [srv.submit(p, media=m, max_new_tokens=5) for p, m in reqs]
    _drive(srv)
    for r in rids:
        # every request reaches a terminal state — finished normally or
        # explicitly shed; none lost/hung
        assert srv.items[r].req.finish_reason in ("length", "stop", "error")
    assert_all_reclaimed(srv)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fault_sweep_fixed_seeds(llava, seed):
    _sweep_one(llava, seed)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_fault_sweep_property(seed):
    cfg = reduced_cfg("llava-1.5-7b")
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    _sweep_one((cfg, params), seed)


# ---------------------------------------------------------------------------
# hardened HTTP front
# ---------------------------------------------------------------------------
@pytest.fixture()
def http_front(llava):
    from http.server import ThreadingHTTPServer

    from repro.launch.serve import make_handler

    cfg, params = llava
    engine = Engine(cfg, params, DisaggConfig({"EPD": 1})).start()
    httpd = ThreadingHTTPServer(("127.0.0.1", 0),
                                make_handler(engine, cfg))
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    yield httpd.server_address[1], cfg, engine
    httpd.shutdown()
    httpd.server_close()
    engine.close(drain_timeout=0)


def _post(port, body):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=120)
    conn.request("POST", "/v1/chat/completions",
                 body if isinstance(body, str) else json.dumps(body),
                 {"Content-Type": "application/json"})
    return conn, conn.getresponse()


def test_http_unknown_model_404(http_front):
    port, cfg, _ = http_front
    conn, resp = _post(port, {"model": "gpt-oss-419b",
                              "messages": [{"content": "hi"}]})
    assert resp.status == 404
    err = json.loads(resp.read())["error"]
    conn.close()
    assert err["type"] == "model_not_found" and cfg.name in err["message"]


def test_http_limits_400(http_front):
    from repro.launch.serve import MAX_IMAGES

    port, cfg, _ = http_front
    img = {"type": "image_url", "image_url": {"url": "http://x/a.png"}}
    too_many = {"messages": [{"content": [img] * (MAX_IMAGES + 1)}]}
    bad_max = {"messages": [{"content": "hi"}], "max_tokens": 0}
    huge = {"messages": [{"content": "w " * 9000}]}
    for body, frag in ((too_many, "too many images"),
                      (bad_max, "max_tokens"),
                      (huge, "prompt too long")):
        conn, resp = _post(port, body)
        assert resp.status == 400
        err = json.loads(resp.read())["error"]
        conn.close()
        assert err["type"] == "invalid_request_error"
        assert frag in err["message"]


def test_http_overloaded_503(llava):
    from http.server import ThreadingHTTPServer

    from repro.launch.serve import make_handler

    cfg, params = llava
    engine = Engine(cfg, params, DisaggConfig({"EPD": 1}),
                    shed_policy="deadline")
    engine.server.kill_instance(0)      # capacity gone before any submit
    httpd = ThreadingHTTPServer(("127.0.0.1", 0),
                                make_handler(engine, cfg))
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    try:
        conn, resp = _post(httpd.server_address[1],
                           {"messages": [{"content": "hi"}]})
        assert resp.status == 503
        err = json.loads(resp.read())["error"]
        conn.close()
        assert err["type"] == "overloaded_error"
    finally:
        httpd.shutdown()
        httpd.server_close()
        engine.close(drain_timeout=0)


def test_serve_cli_fault_knobs():
    from repro.launch.serve import _fault_kwargs, main  # noqa: F401
    import argparse

    ns = argparse.Namespace(fault="crash@5:1,drop@9", shed="deadline")
    kw = _fault_kwargs(ns)
    assert kw["shed_policy"] == "deadline"
    assert [e.kind for e in kw["fault_plan"].events] == ["crash", "drop"]
    assert _fault_kwargs(argparse.Namespace(fault="", shed="")) == {}


# ---------------------------------------------------------------------------
# bench smoke
# ---------------------------------------------------------------------------
def test_bench_fault_recovery_smoke(tmp_path, monkeypatch):
    import benchmarks.bench_fault_recovery as bench

    monkeypatch.setattr(bench, "N", 3)
    monkeypatch.setattr(bench, "RATE", 20.0)
    monkeypatch.setattr(bench, "MAX_NEW", 4)
    monkeypatch.setattr(bench, "CRASH_ITER", 4)
    bench._params_cache.clear()
    out = tmp_path / "faults.json"
    rows = bench.run(out=out)
    data = json.loads(out.read_text())
    assert data["lost_requests"] == 0
    assert data["token_parity"]["matched"] == data["token_parity"]["total"]
    assert any(name == "faults/lost" for name, _, _ in rows)
