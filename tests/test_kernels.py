"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cache_write.ops import cache_write
from repro.kernels.cache_write.ref import cache_write_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype, f32=3e-5, bf16=3e-2):
    return bf16 if dtype == jnp.bfloat16 else f32


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,Kh,Sq,Sk,D,causal,window", [
    (2, 4, 2, 128, 128, 64, True, 0),      # GQA causal
    (1, 4, 4, 256, 256, 64, True, 0),      # MHA
    (2, 2, 1, 100, 100, 32, True, 0),      # ragged (pad path), MQA
    (1, 4, 2, 64, 192, 64, False, 0),      # cross attention
    (1, 4, 4, 256, 256, 64, True, 64),     # sliding window
    (2, 8, 2, 128, 128, 128, True, 0),     # MXU-width heads
])
def test_flash_attention(rng, dtype, B, H, Kh, Sq, Sk, D, causal, window):
    q = jnp.asarray(rng.standard_normal((B, H, Sq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Kh, Sk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Kh, Sk, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,Kh,D,page,max_pages,n_pages,window", [
    (2, 4, 2, 64, 16, 4, 32, 0),
    (3, 8, 8, 128, 16, 8, 64, 0),
    (1, 4, 1, 64, 32, 3, 16, 0),
    (2, 4, 2, 64, 16, 4, 32, 24),    # sliding window straddles pages
    (2, 4, 4, 64, 16, 4, 32, 16),    # window == one page
])
def test_paged_attention(rng, dtype, B, H, Kh, D, page, max_pages, n_pages,
                         window):
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
    kp = jnp.asarray(rng.standard_normal((n_pages, page, Kh, D)), dtype)
    vp = jnp.asarray(rng.standard_normal((n_pages, page, Kh, D)), dtype)
    bt = jnp.asarray(rng.permutation(n_pages)[:B * max_pages]
                     .reshape(B, max_pages), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, page * max_pages + 1, B), jnp.int32)
    out = paged_attention(q, kp, vp, bt, lengths, interpret=True,
                          window=window)
    ref = paged_attention_ref(q, kp, vp, bt, lengths, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=_tol(dtype))


def test_paged_attention_window_masks_prefix(rng):
    """With a window, tokens before lengths-window must not contribute."""
    B, H, Kh, D, page, P = 1, 2, 1, 32, 16, 4
    kp = jnp.asarray(rng.standard_normal((P, page, Kh, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((P, page, Kh, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    bt = jnp.arange(P, dtype=jnp.int32)[None]
    lengths = jnp.asarray([3 * page], jnp.int32)
    out = paged_attention_ref(q, kp, vp, bt, lengths, window=page)
    # corrupting the out-of-window prefix changes nothing
    kp2 = kp.at[0].set(999.0)
    vp2 = vp.at[0].set(-999.0)
    out2 = paged_attention_ref(q, kp2, vp2, bt, lengths, window=page)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_paged_token_write_multi_tensor(rng):
    """One fused launch appends one row per request into every tensor of a
    chosen layer of a [T, L, NB, bs, w] store.  (The underlying cache_write
    donates its input, so each call gets a fresh device array.)"""
    from repro.kernels.cache_write.ops import paged_token_write
    T, L, NB, bs, w, B = 2, 3, 4, 8, 16, 3
    data_np = rng.standard_normal((T, L, NB, bs, w)).astype(np.float32)
    rows = jnp.asarray(rng.standard_normal((T, B, w)), jnp.float32)
    slots_np = [0, 9, 25]                          # (block, off) mixes
    slots = jnp.asarray(slots_np, jnp.int32)
    ref = data_np.copy()
    for t in range(T):
        for b, s in enumerate(slots_np):
            ref[t, 1, s // bs, s % bs] = rows[t, b]
    for kw in ({"use_kernel": False}, {"interpret": True}):
        out = paged_token_write(jnp.asarray(data_np), 1, rows, slots, **kw)
        np.testing.assert_array_equal(np.asarray(out), ref)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("nb,bs,w,T", [(8, 16, 128, 5), (4, 576, 256, 3),
                                       (16, 16, 64, 16)])
def test_cache_write(rng, dtype, nb, bs, w, T):
    cache = jnp.asarray(rng.standard_normal((nb, bs, w)), dtype)
    new = jnp.asarray(rng.standard_normal((T, w)), dtype)
    slots = jnp.asarray(rng.choice(nb * bs, T, replace=False), jnp.int32)
    ref = cache_write_ref(cache, new, slots)
    out = cache_write(cache.copy(), new, slots, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d,N,bd,ch", [
    (2, 64, 128, 16, 64, 32),
    (1, 100, 64, 8, 64, 50),
    (2, 256, 256, 16, 128, 64),
])
def test_selective_scan(rng, dtype, B, S, d, N, bd, ch):
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, d))) * 0.1, dtype)
    x = jnp.asarray(rng.standard_normal((B, S, d)), dtype)
    A = jnp.asarray(-np.abs(rng.standard_normal((d, N))), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), dtype)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), dtype)
    h0 = jnp.asarray(rng.standard_normal((B, d, N)), jnp.float32)
    y, h = selective_scan(dt, x, A, Bm, Cm, h0, block_d=bd, chunk=ch,
                          interpret=True)
    yr, hr = selective_scan_ref(dt, x, A, Bm, Cm, h0)
    np.testing.assert_allclose(y, yr, atol=_tol(dtype, 2e-4, 6e-2))
    np.testing.assert_allclose(h, hr, atol=_tol(dtype, 2e-4, 6e-2))


def test_selective_scan_chunk_continuity(rng):
    """Scanning 2 chunks with carried state == one full scan."""
    B, S, d, N = 1, 64, 32, 8
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, d))) * 0.1)
    x = jnp.asarray(rng.standard_normal((B, S, d)))
    A = jnp.asarray(-np.abs(rng.standard_normal((d, N))), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)))
    Cm = jnp.asarray(rng.standard_normal((B, S, N)))
    y_full, h_full = selective_scan(dt, x, A, Bm, Cm, interpret=True,
                                    block_d=32, chunk=16)
    half = S // 2
    y1, h1 = selective_scan(dt[:, :half], x[:, :half], A, Bm[:, :half],
                            Cm[:, :half], interpret=True, block_d=32, chunk=16)
    y2, h2 = selective_scan(dt[:, half:], x[:, half:], A, Bm[:, half:],
                            Cm[:, half:], h1, interpret=True, block_d=32,
                            chunk=16)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-4)
    np.testing.assert_allclose(h2, h_full, atol=1e-4)
