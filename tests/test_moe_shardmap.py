"""shard_map expert-parallel MoE dispatch == dense MoE (fwd + grad + aux).

Runs in a subprocess with 8 forced host devices (the main test process must
keep the single real device — see conftest)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import model as M, moe, sharding as SH
    from repro.train.train import loss_fn

    cfg = dataclasses.replace(get_config('granite-moe-1b-a400m').reduced(),
                              moe_capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    ref = M.forward(cfg, params, tokens)[0]
    g_ref = jax.grad(lambda p: loss_fn(cfg, p, batch, remat=False)[0])(params)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    SH.set_mesh(mesh)
    moe.MOE_SHARDMAP = True
    out = jax.jit(lambda p, t: M.forward(cfg, p, t)[0])(params, tokens)
    g_sm = jax.jit(jax.grad(
        lambda p: loss_fn(cfg, p, batch, remat=False)[0]))(params)

    assert float(jnp.max(jnp.abs(out - ref))) < 2e-4
    gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sm)))
    assert gerr < 5e-3, gerr
    print("OK")
""")


def test_shardmap_moe_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
