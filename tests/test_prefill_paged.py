"""Batched device-paged chunked prefill: logit + cache parity vs the dense
per-request path, kernel-level sweeps for the chunked paged-attention and
chunk cache-write extensions, and the prefill benchmark registration."""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.engine.runner import ModelRunner, RunnerCaches, bucket_pow2
from repro.kernels.cache_write.ops import paged_chunk_write
from repro.kernels.paged_attention.ops import paged_prefill_attention
from repro.models import layers
from repro.models import model as M

from conftest import reduced_cfg

CHUNK = 11  # not a divisor of KV_BLOCK=16: chunk boundaries straddle blocks


def _setup_pair(arch, rng, *, attn_impl="interpret", n_req=3):
    """Two runners over the same params: dense-gather vs device-paged."""
    cfg = reduced_cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    dense = ModelRunner(cfg, params, RunnerCaches(cfg, kv_blocks=32,
                                                  img_blocks=4))
    paged = ModelRunner(cfg, params,
                        RunnerCaches(cfg, kv_blocks=32, img_blocks=4,
                                     device=True),
                        attn_impl=attn_impl)
    reqs = []
    for rid in range(n_req):
        # heterogeneous lengths: ragged tails exercise chunk padding
        prompt = rng.integers(0, cfg.vocab_size,
                              size=18 + 5 * rid).astype(np.int32)
        media = None
        if cfg.frontend != "none":
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
            dense.encode([(rid, media)])
            paged.encode([(rid, media)])
        reqs.append((rid, prompt))
    return cfg, dense, paged, reqs


def _drive_chunks(cfg, dense, paged, reqs, *, chunk=CHUNK):
    """Chunked prefill to completion; paged runs BATCHED across requests,
    dense per request.  Asserts per-chunk last-token logit parity.  Media
    embeds whole-first (media-then-text chunks)."""
    if cfg.frontend != "none" and not cfg.cross_attention:
        lp = paged.prefill_chunks([(rid, None, True) for rid, _ in reqs])
        for (rid, _), l_p in zip(reqs, lp):
            l_d = dense.prefill_chunk(rid, None, use_media=True)
            scale = np.abs(l_d).max() + 1e-9
            assert np.abs(l_p - l_d).max() / scale < 2e-4
    offs = {rid: 0 for rid, _ in reqs}
    last = {}
    while True:
        items = []
        for rid, prompt in reqs:
            if offs[rid] >= len(prompt):
                continue
            t1 = min(offs[rid] + chunk, len(prompt))
            items.append((rid, prompt[offs[rid]:t1], False))
            offs[rid] = t1
        if not items:
            break
        lp = paged.prefill_chunks(items)
        for (rid, toks, _), l_p in zip(items, lp):
            l_d = dense.prefill_chunk(rid, toks)
            scale = np.abs(l_d).max() + 1e-9
            assert np.abs(l_p - l_d).max() / scale < 2e-4
            last[rid] = int(np.argmax(l_d))
    return last


# ---------------------------------------------------------------------------
# parity: batched paged prefill == dense per-request prefill — per-chunk
# logits AND the resulting cache contents — across attention families
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [
    "llava-1.5-7b",        # dense GQA + media-then-text chunks
    "deepseek-v2-236b",    # MLA (absorbed chunk path over latent pages)
    "whisper-small",       # cross-attention (recomputed from enc_out)
    "gemma3-4b",           # sliding-window local layers
    "zamba2-7b",           # hybrid: shared attention + masked mamba chunks
])
def test_paged_prefill_matches_dense(rng, arch):
    cfg, dense, paged, reqs = _setup_pair(arch, rng)
    last = _drive_chunks(cfg, dense, paged, reqs)
    # cache contents parity: the paged pages hold the same K/V rows
    for name in ("kv", "mla"):
        d_c, p_c = getattr(dense.caches, name), getattr(paged.caches, name)
        if d_c is None:
            continue
        for rid, _ in reqs:
            np.testing.assert_allclose(np.asarray(p_c.gather(rid)),
                                       d_c.gather(rid), atol=2e-4)
    # and decode continues identically off both caches
    rids = [rid for rid, _ in reqs]
    toks = np.array([last[r] for r in rids])
    for _ in range(2):
        l_d = dense.decode(rids, toks)
        l_p = paged.decode(rids, toks)
        scale = np.abs(l_d).max() + 1e-9
        assert np.abs(l_p - l_d).max() / scale < 2e-4
        toks = np.argmax(l_d, axis=-1)


def test_paged_prefill_matches_dense_ref_impl(rng):
    """Same parity through the pure-jnp oracle backend (fast CPU path)."""
    cfg, dense, paged, reqs = _setup_pair("llava-1.5-7b", rng,
                                          attn_impl="ref")
    _drive_chunks(cfg, dense, paged, reqs)


def test_paged_prefill_no_host_cache_traffic(rng):
    """The acceptance property: a batched paged prefill chunk must not
    gather the prior context to the host nor re-append via the host path."""
    cfg, dense, paged, reqs = _setup_pair("llava-1.5-7b", rng)

    def banned(*a, **k):  # pragma: no cover - only hit on regression
        raise AssertionError("prefill touched the host gather/append path")

    for cache in (paged.caches.kv, paged.caches.img):
        cache.gather = banned
        cache.append = banned
    paged.prefill_chunks([(rid, None, True) for rid, _ in reqs])
    paged.prefill_chunks([(rid, p[:8], False) for rid, p in reqs])


def test_paged_prefill_single_call_routes_batched(rng):
    """runner.prefill_chunk on a device cache routes through the batched
    paged path (B=1), not the dense gather fallback."""
    cfg, dense, paged, reqs = _setup_pair("llama3-8b", rng, n_req=1)
    paged._gather_prior = None  # would raise if the dense path ran
    rid, prompt = reqs[0]
    l_p = paged.prefill_chunk(rid, prompt[:8])
    l_d = dense.prefill_chunk(rid, prompt[:8])
    scale = np.abs(l_d).max() + 1e-9
    assert np.abs(l_p - l_d).max() / scale < 2e-4


# ---------------------------------------------------------------------------
# kernel sweeps: chunked paged attention + chunk cache write
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,C,H,Kh,D,page,max_pages,n_pages,window", [
    (2, 8, 4, 2, 64, 16, 4, 32, 0),      # GQA
    (1, 16, 4, 1, 64, 16, 3, 16, 0),     # MQA (the MLA mapping)
    (2, 8, 4, 2, 64, 16, 4, 32, 24),     # sliding window straddles pages
    (3, 4, 4, 4, 32, 8, 5, 24, 0),       # chunk smaller than a page
])
def test_paged_prefill_attention_kernel_vs_ref(rng, dtype, B, C, H, Kh, D,
                                               page, max_pages, n_pages,
                                               window):
    q = jnp.asarray(rng.standard_normal((B, C, H, D)), dtype)
    kp = jnp.asarray(rng.standard_normal((n_pages, page, Kh, D)), dtype)
    vp = jnp.asarray(rng.standard_normal((n_pages, page, Kh, D)), dtype)
    bt = jnp.asarray(rng.permutation(n_pages)[:B * max_pages]
                     .reshape(B, max_pages), jnp.int32)
    ctx = jnp.asarray(rng.integers(0, page * max_pages - C, B), jnp.int32)
    out = paged_prefill_attention(q, kp, vp, bt, ctx, interpret=True,
                                  use_kernel=True, window=window)
    ref = paged_prefill_attention(q, kp, vp, bt, ctx, use_kernel=False,
                                  window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol)


def test_paged_prefill_attention_matches_dense_chunk(rng):
    """Chunk-causality: the paged chunk output equals dense blockwise
    attention over the contiguous prefix+chunk with kv_offset."""
    B, C, H, Kh, D, page, max_pages = 2, 8, 4, 2, 32, 16, 4
    n_pages = B * max_pages
    kp = jnp.asarray(rng.standard_normal((n_pages, page, Kh, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page, Kh, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, C, H, D)), jnp.float32)
    bt = jnp.asarray(np.arange(n_pages).reshape(B, max_pages), jnp.int32)
    ctx = jnp.asarray([13, 30], jnp.int32)   # straddles page boundaries
    out = paged_prefill_attention(q, kp, vp, bt, ctx, use_kernel=False)
    S = max_pages * page
    k = kp[bt].reshape(B, S, Kh, D)
    v = vp[bt].reshape(B, S, Kh, D)
    for b in range(B):
        c0 = int(ctx[b])
        dense = layers.blockwise_attention(
            q[b:b + 1], k[b:b + 1, :c0 + C], v[b:b + 1, :c0 + C],
            causal=True, kv_offset=c0)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(dense[0]),
                                   atol=3e-5)


def test_paged_chunk_write_multi_tensor(rng):
    """One launch writes a whole chunk per request into every tensor of the
    chosen layer of a [T, L, NB, bs, w] store; other layers untouched."""
    T, L, NB, bs, w, B, C = 2, 3, 8, 4, 8, 2, 5
    data_np = rng.standard_normal((T, L, NB, bs, w)).astype(np.float32)
    rows = jnp.asarray(rng.standard_normal((T, B, C, w)), jnp.float32)
    slots = jnp.asarray(rng.permutation(NB * bs)[:B * C].reshape(B, C),
                        jnp.int32)
    for kw in ({"use_kernel": False}, {"interpret": True}):
        out = np.asarray(paged_chunk_write(jnp.asarray(data_np), 1, rows,
                                           slots, **kw))
        exp = data_np.copy()
        flat = exp.reshape(T, L, NB * bs, w)
        for t in range(T):
            for b in range(B):
                for c in range(C):
                    flat[t, 1, int(slots[b, c])] = np.asarray(rows[t, b, c])
        np.testing.assert_array_equal(out, exp)


def test_mamba_masked_chunk_matches_unpadded(rng):
    """The mask= path: a right-padded chunk must return the same state and
    valid outputs as running the unpadded sequence."""
    from repro.models import mamba

    cfg = reduced_cfg("falcon-mamba-7b")
    p = mamba.init_mamba1(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 7, cfg.d_model)), jnp.float32)
    n_valid = [7, 4]
    mask = jnp.asarray(np.arange(7)[None, :] < np.asarray(n_valid)[:, None])
    y_pad, (st_pad, conv_pad) = mamba.mamba1_seq(p, x, cfg, mask=mask)
    for b, n in enumerate(n_valid):
        y, (st, conv) = mamba.mamba1_seq(p, x[b:b + 1, :n], cfg)
        np.testing.assert_allclose(np.asarray(y_pad[b:b + 1, :n]),
                                   np.asarray(y), atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_pad[b:b + 1]),
                                   np.asarray(st), atol=1e-5)
        np.testing.assert_allclose(np.asarray(conv_pad[b:b + 1]),
                                   np.asarray(conv), atol=1e-5)


# ---------------------------------------------------------------------------
# batched-state satellite: per-request cross-KV probing
# ---------------------------------------------------------------------------
def test_batched_state_pads_missing_cross_kv(rng):
    """A decode batch whose FIRST request lacks cross K/V must not drop the
    other requests' entries (the old code probed only sts[0])."""
    cfg = reduced_cfg("whisper-small")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    runner = ModelRunner(cfg, params, RunnerCaches(cfg, kv_blocks=32))
    kvd = cfg.num_kv_heads * cfg.head_dim
    xk = np.ones((1, cfg.media_tokens, kvd), np.float32)
    runner.caches.states.put(0, {})                       # no cross KV
    runner.caches.states.put(1, {"xk0": xk, "xv0": xk})   # has cross KV
    state = runner._batched_state([0, 1], 2)
    ent = state["layers"][0]
    assert "xk" in ent, "cross K/V dropped when lane 0 lacks it"
    assert np.asarray(ent["xk"][1]).max() == 1.0
    assert np.asarray(ent["xk"][0]).max() == 0.0  # padded lane zeros


# ---------------------------------------------------------------------------
# benchmark registration + smoke (CI runs this via pytest)
# ---------------------------------------------------------------------------
def test_bench_prefill_registered_and_smokes(monkeypatch, tmp_path):
    import benchmarks.run as bench_run
    assert "benchmarks.bench_prefill_ttft" in bench_run.MODULES
    assert "benchmarks.bench_prefill_ttft" in bench_run.QUICK

    import benchmarks.bench_prefill_ttft as bench
    monkeypatch.setattr(bench, "B", 2)
    monkeypatch.setattr(bench, "PROMPT_LO", 8)
    monkeypatch.setattr(bench, "PROMPT_HI", 13)
    monkeypatch.setattr(bench, "MAX_NEW", 2)
    bench._drive._params.clear()
    rows = bench.run(out=tmp_path / "BENCH_prefill.json")
    names = [r[0] for r in rows]
    assert "engine/prefill/dense" in names
    assert "engine/prefill/paged-interpret" in names
    assert (tmp_path / "BENCH_prefill.json").exists()
