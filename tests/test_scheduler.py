"""Algorithm-1 / scheduling invariants — unit + hypothesis property tests."""
import math

import pytest
from _hyp import given, settings, st  # optional-dep shim (README.md)

from repro.configs import get_config
from repro.core.batch_scheduler import POLICIES, HydraPolicy
from repro.core.budgets import Budgets, compute_budgets
from repro.core.costmodel import H800, BatchWork, batch_time, stage_cost
from repro.core.request import Request, SLO, Stage
from repro.core.simulator import Cluster, DisaggConfig, Instance, Simulator
from repro.data.workload import PROFILES, make_requests

CFG = get_config("llava-1.5-7b")
SLO_STD = SLO(0.25, 0.04)


def mk_inst(role="EPD", budgets=Budgets(128, 4)):
    return Instance(0, role, CFG, H800, budgets, POLICIES["hydra"])


def mk_req(rid, stage, *, prompt=32, images=1, out=8, done=0):
    r = Request(rid=rid, arrival=0.0, n_images=images,
                image_tokens=576 * images, prompt_tokens=prompt,
                max_new_tokens=out, slo=SLO_STD)
    r.stage = stage
    if stage == Stage.DECODE:
        r.prefill_done = r.prefill_total
        r.tokens_out = 1
        r.first_token_time = 0.0
        r.token_times = [0.0]
    elif stage == Stage.PREFILL:
        r.prefill_done = done
    return r


# ---------------------------------------------------------------------------
# Algorithm 1 unit behaviour
# ---------------------------------------------------------------------------
def test_all_decodes_included():
    inst = mk_inst()
    for i in range(10):
        inst.running.append(mk_req(i, Stage.DECODE))
    b = inst.policy.build(inst, 0.0)
    assert len(b.decode) == 10 and not b.prefill and not b.encode


def test_prefill_chunk_respects_token_budget():
    inst = mk_inst(budgets=Budgets(100, 4))
    inst.running.append(mk_req(0, Stage.PREFILL, prompt=1000, images=0))
    b = inst.policy.build(inst, 0.0)
    assert sum(c for _, c in b.prefill) <= 100


def test_encode_only_when_no_prefill():
    inst = mk_inst()
    inst.running.append(mk_req(0, Stage.PREFILL, prompt=64, images=0))
    inst.running.append(mk_req(1, Stage.ENCODE))
    b = inst.policy.build(inst, 0.0)
    assert b.prefill and not b.encode
    inst2 = mk_inst()
    inst2.running.append(mk_req(1, Stage.ENCODE))
    b2 = inst2.policy.build(inst2, 0.0)
    assert b2.encode and not b2.prefill


def test_role_filters_stages():
    inst = mk_inst(role="E")
    inst.running.append(mk_req(0, Stage.DECODE))
    inst.running.append(mk_req(1, Stage.ENCODE))
    b = inst.policy.build(inst, 0.0)
    assert not b.decode and b.encode


def test_prefill_first_stalls_decodes():
    inst = Instance(0, "EPD", CFG, H800, Budgets(128, 4),
                    POLICIES["prefill_first"])
    inst.running.append(mk_req(0, Stage.DECODE))
    inst.enqueue(mk_req(1, Stage.PREFILL, images=0))
    b = inst.policy.build(inst, 0.0)
    assert b.prefill and not b.decode  # the generation stall, by design


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(n_dec=st.integers(0, 40), n_pre=st.integers(0, 10),
       n_enc=st.integers(0, 10), tau_t=st.integers(16, 512),
       tau_e=st.integers(1, 16), prompt=st.integers(1, 4000))
def test_alg1_budget_invariants(n_dec, n_pre, n_enc, tau_t, tau_e, prompt):
    inst = mk_inst(budgets=Budgets(tau_t, tau_e))
    rid = 0
    for _ in range(n_dec):
        inst.running.append(mk_req(rid, Stage.DECODE))
        rid += 1
    for _ in range(n_pre):
        inst.running.append(mk_req(rid, Stage.PREFILL, prompt=prompt, images=0))
        rid += 1
    for _ in range(n_enc):
        inst.enqueue(mk_req(rid, Stage.ENCODE))
        rid += 1
    b = inst.policy.build(inst, 0.0)
    # (1) every running decode is in the batch
    assert len(b.decode) == n_dec
    # (2) prefill tokens fit in the remaining token budget
    assert len(b.decode) + sum(c for _, c in b.prefill) <= max(tau_t, n_dec)
    # (3) encode runs only if no prefill was scheduled; image budget holds
    if b.prefill:
        assert not b.encode
    assert sum(n for _, n in b.encode) <= max(tau_e, 1)
    # (4) chunks are positive and never exceed what a request still needs
    for r, c in b.prefill:
        assert 0 < c <= r.prefill_remaining


@settings(max_examples=30, deadline=None)
@given(tpot=st.floats(0.005, 0.5))
def test_budget_monotone_in_slo(tpot):
    b1 = compute_budgets(CFG, H800, tpot)
    b2 = compute_budgets(CFG, H800, tpot * 2)
    assert b2.token_budget >= b1.token_budget
    assert b2.image_budget >= b1.image_budget
    # profiled iteration actually fits the SLO (at the reference decode load)
    t = batch_time(CFG, H800, BatchWork(
        decode_batch=64, decode_context=1024,
        prefill_tokens=b1.token_budget, prefill_batch=1,
        prefill_context=b1.token_budget))
    assert t <= tpot * 1.05 or b1.token_budget == 16  # floor case


@settings(max_examples=20, deadline=None)
@given(n_tokens=st.integers(1, 8192), batch=st.integers(1, 64))
def test_costmodel_monotonicity(n_tokens, batch):
    f1, b1 = stage_cost(CFG, "prefill", n_tokens=n_tokens, batch=1,
                        context=n_tokens)
    f2, b2 = stage_cost(CFG, "prefill", n_tokens=2 * n_tokens, batch=1,
                        context=2 * n_tokens)
    assert f2 > f1 and b2 >= b1
    fd1, bd1 = stage_cost(CFG, "decode", batch=batch, context=512)
    fd2, bd2 = stage_cost(CFG, "decode", batch=batch + 1, context=512)
    assert fd2 > fd1 and bd2 >= bd1


def test_parallel_streams_never_slower():
    for imgs in (1, 4, 16):
        for dec in (8, 64, 256):
            w = BatchWork(decode_batch=dec, decode_context=1024,
                          encode_images=imgs)
            tp = batch_time(CFG, H800, w, parallel_streams=True)
            ts = batch_time(CFG, H800, w, parallel_streams=False)
            assert tp <= ts + 1e-9


# ---------------------------------------------------------------------------
# simulator end-to-end invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("counts", [{"EPD": 4}, {"EP": 2, "D": 2},
                                    {"ED": 2, "P": 2},
                                    {"E": 1, "P": 1, "D": 2}])
def test_simulator_completes_and_monotone_tokens(counts):
    prof = PROFILES["textcaps"]
    reqs = make_requests(prof, rate=8.0, n=60,
                         image_tokens_per_image=576, slo=SLO_STD, seed=3)
    cl = Cluster(CFG, H800, DisaggConfig(counts), SLO_STD)
    done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 300)
    assert len(done) == 60
    for r in done:
        assert r.tokens_out == r.max_new_tokens
        assert r.token_times == sorted(r.token_times)
        assert r.first_token_time >= r.arrival
        # stage log ordering: encode before prefill before decode
        names = [n for n, _, _ in r.stage_log]
        if "encode_exec" in names and "prefill_exec" in names:
            assert names.index("encode_exec") < names.index("prefill_exec")


def test_slo_attainment_decreases_with_rate():
    prof = PROFILES["textcaps"]
    cfgm = get_config("llava-next-7b")
    atts = []
    for rate in (8.0, 64.0, 256.0):
        reqs = make_requests(prof, rate=rate, n=150,
                             image_tokens_per_image=2880,
                             slo=SLO(8.0, 0.08), seed=0)
        cl = Cluster(cfgm, H800, DisaggConfig({"EPD": 8}), SLO(8.0, 0.08),
                     policy_name="prefill_first")
        done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 120)
        from repro.core.metrics import slo_attainment
        atts.append(slo_attainment(done))
    assert atts[0] >= atts[-1]
