"""Distribution smoke tests on the real local device(s): the same model code
must produce identical results with and without sharding constraints, and
the dry-run builder must work on a host-size mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import sharding as SH

from conftest import reduced_cfg


@pytest.mark.parametrize("arch", ["llama3-8b", "granite-moe-1b-a400m",
                                  "falcon-mamba-7b"])
def test_constrained_forward_matches_unconstrained(arch):
    cfg = reduced_cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    ref, _, _ = M.forward(cfg, params, tokens)
    mesh = make_host_mesh()
    with SH.use_mesh(mesh):
        out = jax.jit(lambda p, t: M.forward(cfg, p, t)[0])(params, tokens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_param_shardings_cover_tree():
    cfg = reduced_cfg("deepseek-v2-236b")
    pspec = M.param_specs(cfg, jnp.bfloat16)
    mesh = make_host_mesh()
    sh = SH.param_shardings(mesh, pspec)
    n_leaves = len(jax.tree.leaves(pspec))
    n_shard = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_leaves == n_shard


def test_fsdp_shards_more():
    """FSDP must strictly reduce (or keep) per-device parameter bytes."""
    cfg = get_config("llama3-8b")
    pspec = M.param_specs(cfg, jnp.bfloat16)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def per_device_bytes(shardings):
        total = 0
        for leaf, s in zip(jax.tree.leaves(pspec),
                           jax.tree.leaves(shardings,
                                           is_leaf=lambda x: hasattr(x, "spec"))):
            shard = 1
            for name in jax.tree.leaves(tuple(s.spec)):
                if name:
                    shard *= mesh.shape[name]
            total += leaf.size * leaf.dtype.itemsize // max(shard, 1)
        return total

    base = per_device_bytes(SH.param_shardings(mesh, pspec))
    fsdp = per_device_bytes(SH.param_shardings(mesh, pspec, fsdp=True))
    assert fsdp <= base


def test_dryrun_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dimensions={0}
  %ar = f32[64]{0} all-reduce(f32[64]{0} %y), to_apply=%sum
  %aa = bf16[4,32]{1,0} all-to-all(bf16[4,32]{1,0} %z), dimensions={0}
  %cp = u32[2]{0} collective-permute(u32[2]{0} %w)
  %dot = f32[8,8]{1,0} dot(f32[8,4] %a, f32[4,8] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["all-to-all"] == 4 * 32 * 2
    assert out["collective-permute"] == 2 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))
