"""Streaming engine API tests (DESIGN.md §13): step-driven continuous
serving, fused on-device sampling, stop tokens, abort, multi-image
requests, the OpenAI-style HTTP front, and the serving SLO benchmark."""
import http.client
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.request import SamplingParams, Stage
from repro.core.simulator import DisaggConfig
from repro.engine.api import Engine
from repro.engine.server import HydraServer
from repro.models import model as M

from conftest import assert_all_reclaimed, reduced_cfg


@pytest.fixture(scope="module")
def llava():
    cfg = reduced_cfg("llava-1.5-7b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(5))


def _quickstart_workload(cfg, rng, n=4, prompt_len=10):
    reqs = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
        media = None
        if i % 2 == 0:
            media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                     * 0.1).astype(np.float32)
        reqs.append((prompt, media))
    return reqs


def _assert_all_free(server):
    # sharing-aware reclaim invariants (conftest) + the strict no-evictable
    # check: these engines run with the prefix cache OFF, so nothing may
    # park in the evictable pool either
    assert_all_reclaimed(server)
    for inst in server.instances:
        for c in (inst.caches.kv, inst.caches.mla, inst.caches.img):
            if c is not None:
                assert c.allocator.n_free == c.allocator.num_blocks, \
                    f"inst {inst.iid}: {c.allocator.n_free} free of " \
                    f"{c.allocator.num_blocks}"


# ---------------------------------------------------------------------------
# greedy streaming == legacy closed-loop run()
# ---------------------------------------------------------------------------
def test_streaming_greedy_matches_legacy_run(rng, llava):
    cfg, params = llava
    reqs = _quickstart_workload(cfg, rng)
    disagg = DisaggConfig({"E": 1, "P": 1, "D": 1})

    srv = HydraServer(cfg, params, disagg)
    rids = [srv.submit(p, media=m, max_new_tokens=6) for p, m in reqs]
    legacy = [srv.run()[r].generated for r in rids]

    eng = Engine(cfg, params, disagg)
    streams = [eng.generate(p, media=m,
                            sampling=SamplingParams(max_tokens=6))
               for p, m in reqs]
    assert [s.tokens() for s in streams] == legacy

    # event stream structure: first_token, deltas, then a finish event
    evs = list(eng.generate(reqs[0][0], media=reqs[0][1], max_new_tokens=3))
    assert [e.kind for e in evs] == ["first_token", "token", "token",
                                    "finish"]
    assert evs[-1].finish_reason == "length"
    assert [e.token for e in evs[:-1]] == legacy[0][:3]
    _assert_all_free(eng.server)


# ---------------------------------------------------------------------------
# seeded sampling: deterministic across batch compositions
# ---------------------------------------------------------------------------
def test_seeded_sampling_deterministic_across_batches(rng, llava):
    cfg, params = llava
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    sp = SamplingParams(temperature=0.9, top_k=50, top_p=0.95, seed=42,
                        max_tokens=6)
    others = _quickstart_workload(cfg, rng, n=3, prompt_len=13)

    outs = []
    for companions in ([], others[:1], others[1:]):
        eng = Engine(cfg, params, DisaggConfig({"EPD": 1}))
        target = eng.generate(prompt, sampling=sp)
        for p, m in companions:
            eng.generate(p, media=m, sampling=SamplingParams(
                temperature=0.7, seed=7, max_tokens=6))
        eng.drain()
        outs.append(list(eng.result(target.rid).generated))
    assert outs[0] == outs[1] == outs[2]
    assert len(outs[0]) == 6


def test_sample_from_logits_greedy_and_topk1():
    logits = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((4, 64)).astype(np.float32))
    base = {"seed": jnp.arange(4, dtype=jnp.uint32),
            "step": jnp.zeros(4, jnp.int32)}
    greedy = M.sample_from_logits(
        logits, {**base, "temp": jnp.zeros(4),
                 "top_k": jnp.zeros(4, jnp.int32), "top_p": jnp.ones(4)})
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.argmax(np.asarray(logits), -1))
    # top_k=1 collapses to argmax at any temperature
    k1 = M.sample_from_logits(
        logits, {**base, "temp": jnp.full(4, 1.3),
                 "top_k": jnp.ones(4, jnp.int32), "top_p": jnp.ones(4)})
    np.testing.assert_array_equal(np.asarray(k1),
                                  np.argmax(np.asarray(logits), -1))
    # sampled tokens only come from the top-k set
    k4 = M.sample_from_logits(
        logits, {**base, "temp": jnp.full(4, 2.0),
                 "top_k": jnp.full(4, 4, jnp.int32), "top_p": jnp.ones(4)})
    top4 = np.argsort(np.asarray(logits), -1)[:, -4:]
    for b in range(4):
        assert int(k4[b]) in top4[b]


# ---------------------------------------------------------------------------
# stop tokens
# ---------------------------------------------------------------------------
def test_stop_token_early_exit(rng, llava):
    cfg, params = llava
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    full = eng.generate(prompt, sampling=SamplingParams(max_tokens=8)) \
        .tokens()
    # first position whose token hasn't occurred earlier (so the truncated
    # run can't stop prematurely on a repeat)
    i = next(i for i, t in enumerate(full) if t not in full[:i])
    st = eng.generate(prompt, sampling=SamplingParams(
        max_tokens=8, stop=(full[i],)))
    assert st.tokens() == full[:i]
    req = eng.result(st.rid).req
    assert req.finish_reason == "stop" and req.done
    _assert_all_free(eng.server)


# ---------------------------------------------------------------------------
# abort at every stage frees all blocks
# ---------------------------------------------------------------------------
def _step_until(eng, req, stage, max_iters=200):
    for _ in range(max_iters):
        if req.stage == stage:
            return True
        eng.step()
    return req.stage == stage


@pytest.mark.parametrize("stage", [Stage.ENCODE, Stage.PREFILL,
                                   Stage.DECODE])
def test_abort_frees_blocks_at_stage(rng, llava, stage):
    cfg, params = llava
    eng = Engine(cfg, params, DisaggConfig({"E": 1, "P": 1, "D": 1}))
    media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
             * 0.1).astype(np.float32)
    # 200-token prompt: prefill spans multiple 64-token-budget chunks, so
    # the PREFILL stage is observable across steps
    victim = eng.generate(rng.integers(0, cfg.vocab_size, 200)
                          .astype(np.int32), media=media,
                          sampling=SamplingParams(max_tokens=64))
    bystander = eng.generate(rng.integers(0, cfg.vocab_size, 6)
                             .astype(np.int32),
                             sampling=SamplingParams(max_tokens=4))
    req = eng.result(victim.rid).req
    assert _step_until(eng, req, stage)
    assert eng.abort(victim.rid)
    assert req.finish_reason == "abort" and req.done
    evs = list(victim)                      # stream ends with the abort
    assert evs[-1].kind == "finish" and evs[-1].finish_reason == "abort"
    eng.drain()                             # bystander still completes
    assert len(eng.result(bystander.rid).generated) == 4
    _assert_all_free(eng.server)
    assert not eng.abort(victim.rid)        # double-abort is a no-op


def test_stream_deadlock_guard_raises(rng, llava):
    """A request that can never fit must raise the capacity-deadlock
    diagnostic from a step-driven stream, not hang the consumer."""
    cfg, params = llava
    eng = Engine(cfg, params, DisaggConfig({"EPD": 1}), kv_blocks=4)
    st = eng.generate(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                      sampling=SamplingParams(max_tokens=128))
    with pytest.raises(RuntimeError, match="capacity deadlock"):
        list(st)


def test_abort_mid_migration_parked_request(rng, llava):
    """Abort a request sitting in an instance's *waiting* queue."""
    cfg, params = llava
    eng = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    rid = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                     max_new_tokens=8)
    assert eng.abort(rid)                   # still queued, never scheduled
    eng.drain()
    _assert_all_free(eng.server)


# ---------------------------------------------------------------------------
# prefill-path DONE no longer leaks cache blocks (satellite fix)
# ---------------------------------------------------------------------------
def test_prefill_done_path_frees_blocks(rng, llava):
    cfg, params = llava
    srv = HydraServer(cfg, params, DisaggConfig({"E": 1, "P": 1, "D": 1}))
    for i in range(3):
        media = (rng.standard_normal((cfg.media_tokens, cfg.d_model))
                 * 0.1).astype(np.float32) if i % 2 == 0 else None
        # max_new_tokens=1: the request reaches DONE on the prefill path
        srv.submit(rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
                   media=media, max_new_tokens=1)
    out = srv.run()
    assert all(len(it.generated) == 1 for it in out.values())
    _assert_all_free(srv)


# ---------------------------------------------------------------------------
# multi-image requests (satellite fix)
# ---------------------------------------------------------------------------
def test_multi_image_request_matches_concat(rng, llava):
    cfg, params = llava
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    # deliberately DIFFERENT per-image shapes: the encoder batches per
    # shape group but must commit embeddings in submission order
    imgs = [(rng.standard_normal((n, cfg.d_model)) * 0.1).astype(np.float32)
            for n in (12, cfg.media_tokens)]

    # reference: one prefill over the concatenated media + greedy decode
    cat = np.concatenate(imgs, axis=0)
    last, pc = M.prefill(cfg, params, jnp.asarray(prompt)[None],
                         media=jnp.asarray(cat)[None])
    S_tot = len(prompt) + cat.shape[0]
    cache = M.build_cache_from_prefill(cfg, pc, max_len=S_tot + 6)
    ref = [int(jnp.argmax(last[0]))]
    cl = S_tot
    for _ in range(4):
        lg, cache = M.decode_step(cfg, params, cache, jnp.int32(cl),
                                  jnp.asarray([[ref[-1]]], jnp.int32))
        ref.append(int(jnp.argmax(lg[0])))
        cl += 1

    eng = Engine(cfg, params, DisaggConfig({"E": 1, "P": 1, "D": 1}))
    st = eng.generate(prompt, media=imgs,
                      sampling=SamplingParams(max_tokens=5))
    req = eng.result(st.rid).req
    assert req.n_images == 2
    assert req.image_tokens == sum(m.shape[0] for m in imgs)
    assert st.tokens() == ref
    _assert_all_free(eng.server)


# ---------------------------------------------------------------------------
# open-loop submission: requests join a live loop
# ---------------------------------------------------------------------------
def test_open_loop_submit_while_running(rng, llava):
    cfg, params = llava
    eng = Engine(cfg, params, DisaggConfig({"EPD": 1}))
    first = eng.generate(rng.integers(0, cfg.vocab_size, 6)
                         .astype(np.int32),
                         sampling=SamplingParams(max_tokens=10))
    for _ in range(4):                      # first request is mid-flight
        eng.step()
    late = eng.generate(rng.integers(0, cfg.vocab_size, 6)
                        .astype(np.int32),
                        sampling=SamplingParams(max_tokens=3))
    assert eng.result(late.rid).req.arrival > 0.0
    eng.drain()
    assert len(eng.result(first.rid).generated) == 10
    assert len(eng.result(late.rid).generated) == 3
    _assert_all_free(eng.server)


# ---------------------------------------------------------------------------
# OpenAI-style HTTP front
# ---------------------------------------------------------------------------
@pytest.fixture()
def http_front(llava):
    from http.server import ThreadingHTTPServer

    from repro.launch.serve import make_handler

    cfg, params = llava
    engine = Engine(cfg, params, DisaggConfig({"EPD": 1})).start()
    httpd = ThreadingHTTPServer(("127.0.0.1", 0),
                                make_handler(engine, cfg))
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    yield httpd.server_address[1], cfg, engine
    httpd.shutdown()
    httpd.server_close()
    engine.close()


def _post(port, body):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=120)
    conn.request("POST", "/v1/chat/completions", json.dumps(body),
                 {"Content-Type": "application/json"})
    return conn, conn.getresponse()


def test_http_chat_completion(http_front):
    port, cfg, engine = http_front
    conn, resp = _post(port, {
        "messages": [{"role": "user", "content": [
            {"type": "text", "text": "describe this image"},
            {"type": "image_url", "image_url": {"url": "http://x/cat.png"}},
        ]}],
        "max_tokens": 3})
    assert resp.status == 200
    out = json.loads(resp.read())
    conn.close()
    assert out["object"] == "chat.completion"
    assert out["choices"][0]["finish_reason"] == "length"
    assert out["usage"]["completion_tokens"] == 3
    assert out["choices"][0]["message"]["content"].count("<") == 3
    # the front releases finished requests: no per-request state retained
    assert not engine._queues and not engine.server.items


def test_http_chat_streaming(http_front):
    port, cfg, _ = http_front
    conn, resp = _post(port, {
        "messages": [{"role": "user", "content": "hello there"}],
        "max_tokens": 3, "stream": True, "temperature": 0.5, "seed": 3})
    assert resp.status == 200
    assert resp.getheader("Content-Type").startswith("text/event-stream")
    lines = [ln for ln in resp.read().decode().splitlines() if ln]
    conn.close()
    assert lines[-1] == "data: [DONE]"
    chunks = [json.loads(ln[len("data: "):]) for ln in lines[:-1]]
    deltas = [c["choices"][0]["delta"] for c in chunks]
    assert deltas[0].get("role") == "assistant"
    assert sum("content" in d for d in deltas) == 3
    assert chunks[-1]["choices"][0]["finish_reason"] == "length"


def test_http_models_and_errors(http_front):
    port, cfg, _ = http_front
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    conn.request("GET", "/v1/models")
    out = json.loads(conn.getresponse().read())
    assert out["data"][0]["id"] == cfg.name
    # malformed bodies get a 400 with an error object, never a dropped
    # connection: missing messages, non-object body, non-object message
    for bad in ("{}", "[1,2]", '{"messages":["hi"]}',
                '{"messages":[{"content":[42]}]}'):
        conn.request("POST", "/v1/chat/completions", bad,
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 400
        assert "error" in json.loads(resp.read())
    conn.close()


def test_parse_chat_request_stop_tokens(llava):
    from repro.launch.serve import encode_text, parse_chat_request

    cfg, _ = llava
    prompt, media, sp, stream = parse_chat_request({
        "messages": [{"role": "user", "content": "a b c"}],
        "stop": "done", "stop_token_ids": [7], "temperature": 0.3,
        "top_p": 0.9, "max_tokens": 5}, cfg)
    assert media is None and not stream
    assert len(prompt) == 3
    assert 7 in sp.stop
    assert int(encode_text("done", cfg.vocab_size)[0]) in sp.stop
    assert sp.temperature == pytest.approx(0.3)
    assert sp.max_tokens == 5


# ---------------------------------------------------------------------------
# benchmark registration + smoke (CI runs this via pytest)
# ---------------------------------------------------------------------------
def test_bench_serving_registered_and_smokes(monkeypatch, tmp_path):
    import benchmarks.run as bench_run
    assert "benchmarks.bench_serving_slo" in bench_run.MODULES
    assert "benchmarks.bench_serving_slo" in bench_run.QUICK

    import benchmarks.bench_serving_slo as bench
    monkeypatch.setattr(bench, "N", 3)
    monkeypatch.setattr(bench, "RATE", 50.0)
    monkeypatch.setattr(bench, "MAX_NEW", 3)
    bench._params_cache.clear()
    rows = bench.run(out=tmp_path / "BENCH_serving.json")
    names = [r[0] for r in rows]
    assert "serving/p90_ttft" in names and "serving/attainment" in names
    rec = json.loads((tmp_path / "BENCH_serving.json").read_text())
    assert rec["n_requests"] == 3
    assert 0.0 <= rec["attainment"] <= 1.0
