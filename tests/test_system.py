"""End-to-end behaviour of the HydraInfer system (paper-level claims)."""
import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-dep shim (README.md)

from repro.configs import get_config
from repro.core.costmodel import H800, BatchWork, batch_time
from repro.core.metrics import goodput, quantile, slo_attainment, summarize
from repro.core.request import Request, SLO, Stage
from repro.core.simulator import Cluster, DisaggConfig, Simulator
from repro.data.workload import IMAGE_TOKENS, PROFILES, make_requests, slo_for

MODEL = "llava-next-7b"


def _run(policy, rate, disagg=None, n=120, seed=0, ds="textcaps"):
    cfg = get_config(MODEL)
    slo = slo_for(MODEL, ds)
    reqs = make_requests(PROFILES[ds], rate=rate, n=n,
                         image_tokens_per_image=IMAGE_TOKENS[MODEL],
                         slo=slo, seed=seed)
    cl = Cluster(cfg, H800, disagg or DisaggConfig({"EPD": 8}), slo,
                 policy_name=policy)
    done = Simulator(cl).run(reqs, until=reqs[-1].arrival + 150)
    return done, reqs


def test_hydra_beats_prefill_first_at_load():
    """Paper headline: stage-level scheduling sustains rates where the
    vLLM-v0-style prefill-first policy violates SLOs (generation stall)."""
    rate = 48.0
    hyd, _ = _run("hydra", rate)
    pf, _ = _run("prefill_first", rate)
    assert slo_attainment(hyd) >= slo_attainment(pf)
    assert slo_attainment(hyd) >= 0.9


def _stall_requests(slo):
    reqs = [Request(rid=i, arrival=0.0, n_images=0, image_tokens=0,
                    prompt_tokens=64, max_new_tokens=100, slo=slo)
            for i in range(2)]
    for rid in (2, 3, 4):  # several arrivals -> a clear stall window
        reqs.append(Request(rid=rid, arrival=0.2, n_images=1,
                            image_tokens=2880, prompt_tokens=64,
                            max_new_tokens=16, slo=slo))
    return reqs


def test_generation_stall_exists_in_prefill_first():
    slo = SLO(8.0, 0.08)
    cfg = get_config(MODEL)
    out = {}
    for policy in ("prefill_first", "hydra"):
        cl = Cluster(cfg, H800, DisaggConfig({"EPD": 1}), slo,
                     policy_name=policy)
        done = Simulator(cl).run(_stall_requests(slo), until=600)
        gaps = [g for r in done if r.rid < 2 for g in r.tpots()]
        out[policy] = max(gaps)
    assert out["prefill_first"] > 1.8 * out["hydra"]


def test_migration_overhead_below_one_percent():
    """Paper Fig 13: image/KV cache migration <1% of request latency."""
    done, _ = _run("hydra", 16.0, DisaggConfig({"E": 1, "P": 3, "D": 4}))
    mig = sum(t1 - t0 for r in done for n, t0, t1 in r.stage_log
              if n == "migrate")
    total = sum(t1 - t0 for r in done for _, t0, t1 in r.stage_log)
    assert mig / total < 0.01


def test_no_fixed_optimal_ratio():
    """Paper §5.3: TPOT anti-correlates with D nodes; extreme ratios hurt
    TTFT — no single ratio dominates."""
    stats = {}
    for k in (1, 4, 7):
        done, reqs = _run("hydra", 24.0, DisaggConfig({"EP": k, "D": 8 - k}))
        stats[k] = summarize(done, 24.0, reqs[-1].arrival)
    assert stats[1].p90_tpot <= stats[7].p90_tpot   # more D -> lower TPOT
    assert stats[1].p90_ttft >= stats[4].p90_ttft   # too few EP -> TTFT up


def test_goodput_bisection():
    def attain(rate):
        return 1.0 if rate <= 10.0 else 0.0

    g = goodput(attain, lo=1.0, hi=16.0, tol=0.5)
    assert 9.0 <= g <= 10.5


@settings(max_examples=25, deadline=None)
@given(st.floats(0.01, 0.2), st.floats(0.2, 8.0))
def test_request_slo_definition(tpot_slo, ttft_slo):
    """meets_slo == TTFT ok AND >=90% of TPOTs within the SLO (paper §2.3)."""
    r = Request(rid=0, arrival=0.0, n_images=0, image_tokens=0,
                prompt_tokens=8, max_new_tokens=21,
                slo=SLO(ttft_slo, tpot_slo))
    r.first_token_time = 0.5
    r.token_times = [0.5 + i * tpot_slo * 0.99 for i in range(21)]
    assert r.meets_slo() == (0.5 <= ttft_slo)
    # violate >10% of the gaps -> SLO must fail regardless of TTFT
    r.token_times = [0.5]
    t = 0.5
    for i in range(20):
        t += tpot_slo * (3.0 if i % 3 == 0 else 0.5)
        r.token_times.append(t)
    assert not r.meets_slo()
