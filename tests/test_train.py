"""Training substrate tests: optimizer, schedule, checkpointing, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-dep shim (README.md)

from repro.configs import get_config
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batches
from repro.train.optim import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.train.train import make_train_step

from conftest import reduced_cfg


def test_loss_decreases_under_training():
    cfg = reduced_cfg("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    state = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt))
    it = batches(cfg, DataConfig(batch_size=4, seq_len=64))
    losses = []
    for _ in range(12):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, state, stats = step(params, state, b)
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


@settings(max_examples=30, deadline=None)
@given(step=st.integers(0, 10_000))
def test_lr_schedule_bounds(step):
    opt = AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)
    lr = float(lr_at(opt, step))
    assert 0.0 <= lr <= opt.lr * 1.0001
    if step >= opt.total_steps:
        assert lr <= opt.lr * opt.min_lr_ratio * 1.01 + 1e-12


def test_grad_clip_bounds_update():
    opt = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    state = init_opt_state(params)
    grads = {"w": jnp.full((4, 4), 1e6)}
    _, _, stats = adamw_update(opt, params, grads, state)
    assert float(stats["grad_norm"]) > 1e5  # raw norm reported


def test_checkpoint_roundtrip_exact():
    cfg = reduced_cfg("granite-moe-1b-a400m")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    state = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "c.npz")
        ckpt.save(p, {"params": params, "opt": state})
        back = ckpt.load(p, {"params": params, "opt": state})
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves({"params": params,
                                                            "opt": state})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_shapes_and_padding():
    cfg = reduced_cfg("pixtral-12b")
    it = batches(cfg, DataConfig(batch_size=3, seq_len=48))
    b = next(it)
    assert b["tokens"].shape == (3, 48)
    assert b["labels"].shape == (3, 48)
    assert b["media"].shape == (3, cfg.media_tokens, cfg.d_model)
    assert (b["labels"] == -1).any()          # packing boundaries present
    assert (b["tokens"] >= 0).all()
    assert b["tokens"].max() < cfg.vocab_size


def test_data_deterministic():
    cfg = reduced_cfg("llama3-8b")
    b1 = next(batches(cfg, DataConfig(seed=7)))
    b2 = next(batches(cfg, DataConfig(seed=7)))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
